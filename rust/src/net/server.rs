//! The `serve` role: the distributed delayed-update server loop over TCP.
//!
//! [`BoundServer`] hosts the same delayed-update semantics as the
//! in-process async engine ([`crate::coordinator::apbcfw`]): workers solve
//! block subproblems against (possibly stale) parameter snapshots, the
//! server assembles tau disjoint blocks across their payloads, applies
//! with the paper's step size, and drops anything staler than `k/2`
//! (Theorem 4). The apply/accounting step itself — staleness verdict,
//! delay stamping, step schedule, gap EMA, averaging, sample/stop checks
//! — is NOT implemented here: it lives in the transport-agnostic
//! [`ApplyCore`](crate::coordinator::apply::ApplyCore), shared verbatim
//! with the in-process engine. This module supplies only the transport:
//! updates arrive as wire frames, snapshots leave as full vectors or
//! dirty-range deltas, and the fleet is managed over real sockets.
//!
//! Each serve loop stays single-threaded over its master parameter; one
//! reader thread per connection decodes frames into the loop's event
//! channel, and every write (handshake, snapshots, shutdown) is issued by
//! the loop itself. Per connection the protocol strictly alternates — a
//! worker has at most one request in flight — which is what rules out
//! write-write deadlocks and, at one worker, makes the whole solve
//! deterministic (the loopback equivalence tests pin it bit-identical to
//! the in-process delayed engine).
//!
//! The fleet is **elastic** (protocol v2): the listener stays open for
//! the whole run, so workers can join mid-run (each gets a fresh
//! server-issued id and therefore a fresh block-sampling rng stream) and
//! leave or crash without stalling the solve — a dead connection's
//! in-flight blocks are requeued into the sampling pool (`workers_lost` /
//! `blocks_requeued` telemetry). With `run.liveness_ms` set, a connection
//! silent for that long is declared dead even if the socket never errors
//! (the unplugged-cable case); workers send heartbeats at a third of that
//! window. The loop waits on the earliest of its deadlines (event
//! arrival, accept poll, liveness scan, empty-fleet grace, time budget)
//! instead of busy-polling, and readers feed the bounded event channel
//! with counted backpressure (`event_stalls`) rather than unbounded
//! buffering. All of it is strictly no-op by default: with no joiners, no
//! deaths, no liveness and no chaos, the frames exchanged and the event
//! ordering are exactly those of the fixed-fleet v1 loop.
//!
//! The parameter plane is **sharded** (protocol v3, `run.shards = S`):
//! bind carves the blocks and the parameter vector into S contiguous
//! spans ([`ShardPlan`]) and runs one serve loop per hosted shard, each
//! owning its block range, its slice of the parameter, and its own
//! [`ApplyCore`]. Workers learn the plan from the Hello handshake, route
//! each Update frame to its block's owner, and fan snapshot pulls out to
//! every shard under a per-shard version vector. A thin rendezvous
//! ([`BoundServer::run`]) joins the shard loops and aggregates their
//! per-shard counters into one [`Report`]; any shard finishing (budget,
//! target, failure) stops the whole plane. `run.shards = 1` takes the
//! exact historical single-loop path, pinned bit-identical by the
//! loopback equivalence tests.

use super::checkpoint::{self, Checkpoint};
use super::shard::{self, ShardPlan};
use super::wire::{self, Hello, Msg, SnapshotBody};
use super::{merge_ranges, payload_mode_tag, NetOptions};
use crate::coordinator::apply::{ApplyCore, ApplyKnobs};
use crate::coordinator::{RunResult, UpdateMsg};
use crate::problems::{BlockOracle, Problem};
use crate::run::{
    Engine, Observer, ProblemInstance, Report, Runner, RunSpec, StragglerSpec,
};
use crate::util::config::Config;
use crate::util::metrics::Counters;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::VecDeque;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// How often a serve loop polls the (nonblocking) listener for mid-run
/// joiners; also the ceiling on how long an idle loop sleeps between
/// housekeeping passes.
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Dirty-range history depth: a worker more than this many versions
/// behind is resynced with a full snapshot instead of a delta.
const DELTA_LOG_CAP: usize = 256;

/// Parameter ranges one apply dirtied; `None` marks a dense
/// whole-parameter write (no delta possible across it).
type DirtyRanges = Option<Vec<Range<usize>>>;

/// How one shard serve *session* ended: normally (budget, target,
/// sibling stop), or through an injected `run.chaos = crash:K` abort.
/// A crash makes [`BoundServer::run_shard`] re-enter the session with
/// the just-written checkpoint and the next generation — the in-process
/// analogue of killing and restarting the serve process.
enum SessionEnd {
    /// The run is over: the finished per-shard result.
    Finished(Box<RunResult>),
    /// The injected crash fired: restart the session with restore.
    Crashed,
}

/// Events the per-connection reader threads feed the server loop.
enum Event {
    /// A decoded multi-block update payload from connection `conn`.
    Update { conn: usize, msg: UpdateMsg },
    /// A snapshot request from connection `conn` holding `have`.
    SnapReq { conn: usize, have: u64 },
    /// Connection `conn` closed or failed.
    Gone { conn: usize },
}

/// Server-side state of one worker connection. Slots are never removed —
/// a dead connection keeps its index (with `stream` taken) so the `conn`
/// indices carried by reader events stay stable for the whole run.
struct ConnState {
    /// Write half owned by the server loop; `None` once dead.
    stream: Option<TcpStream>,
    /// Server-issued worker id: the rng stream selector and the key under
    /// which the assembler tracks this worker's pending updates.
    worker_id: u32,
    /// Milliseconds since the loop epoch of the last frame this
    /// connection's reader decoded (any frame — heartbeats included).
    last_seen: Arc<AtomicU64>,
    /// Blocks handed out with the last snapshot answer and not yet
    /// returned as an update — requeued if the worker dies mid-round.
    outstanding: usize,
    /// Oracle count of this worker's last nonempty Update frame — the
    /// serve side's view of its fan-out batch. Tracked only under
    /// `run.adapt.batch = auto`, where a length transition is a resize
    /// decided by the worker's controller (`batch_resizes` telemetry —
    /// no wire change needed to observe it).
    last_batch: Option<usize>,
}

/// Declare connection `idx` dead (idempotent): shut the socket down so
/// its reader unblocks, return its in-flight blocks to the sampling pool
/// (the outstanding fan-out round plus anything of its still buffered in
/// the core's assembler — block sampling is with replacement, so freed
/// blocks are immediately drawable again), and count the loss.
fn kill_conn<P: Problem>(
    conns: &mut [ConnState],
    idx: usize,
    alive: &mut usize,
    core: &mut ApplyCore<'_, P>,
    counters: &Counters,
) {
    let c = &mut conns[idx];
    if let Some(stream) = c.stream.take() {
        stream.shutdown(std::net::Shutdown::Both).ok();
        *alive -= 1;
        Counters::bump(&counters.workers_lost);
        let requeued =
            c.outstanding + core.requeue_worker(c.worker_id as usize);
        c.outstanding = 0;
        Counters::add(&counters.blocks_requeued, requeued as u64);
    }
}

/// A validated, bound (but not yet running) serve-role instance. Binding
/// is split from running so callers can learn the listen address — port 0
/// resolves to an ephemeral port — before starting workers against it
/// (the loopback self-hosted mode does exactly that). With
/// `run.shards > 1` this binds one listener per hosted shard and carries
/// the block→shard [`ShardPlan`] every handshake ships.
pub struct BoundServer {
    /// One listener per hosted shard (parallel to `hosted`).
    listeners: Vec<TcpListener>,
    /// Shard ids this process hosts — all of them by default, exactly
    /// one under `run.shard_id` (the multi-process deployment).
    hosted: Vec<usize>,
    /// The session's block→shard partition (degenerate at one shard).
    plan: ShardPlan,
    spec: RunSpec,
    instance: ProblemInstance,
    /// Flattened config shipped in the handshake so workers rebuild the
    /// identical problem instance.
    config_pairs: Vec<(String, String)>,
    /// Fleet-management knobs (accept deadline, liveness, chaos, shard
    /// count) — validated at bind time, shipped to workers via the
    /// handshake.
    opts: NetOptions,
}

/// Dispatch [`ShardPlan::build`] over the registered problem enum.
fn build_plan(
    instance: &ProblemInstance,
    addrs: Vec<String>,
) -> Result<ShardPlan> {
    match instance {
        ProblemInstance::Gfl(p) => ShardPlan::build(p, addrs),
        ProblemInstance::Qp(p) => ShardPlan::build(p, addrs),
        ProblemInstance::Chain(p) => ShardPlan::build(p, addrs),
        ProblemInstance::Multiclass(p) => ShardPlan::build(p, addrs),
    }
}

impl BoundServer {
    /// Validate `spec` against the serve role and `problem`, and bind the
    /// listen socket(s). The spec must name the `async` engine (its tau,
    /// staleness-rule, collision and sampling knobs drive the server
    /// loop); the in-process simulation knobs (stragglers, work
    /// multipliers) are rejected — on a real transport the network itself
    /// supplies the delays the paper models.
    ///
    /// Sharded binds (`run.shards = S > 1`) additionally reject knobs
    /// that need the whole parameter on one host (weighted averaging,
    /// exact gaps), carve the [`ShardPlan`], and bind shard `s` on
    /// `port + s` (or S ephemeral ports when `addr` ends in `:0`).
    pub fn bind(
        spec: RunSpec,
        problem: &str,
        cfg: &Config,
        addr: &str,
    ) -> Result<BoundServer> {
        // Full spec validation (worker counts, cadences, batch scoping).
        let runner = Runner::new(spec.clone())?;
        match &spec.engine {
            Engine::Async {
                straggler,
                work_multiplier,
                ..
            } => {
                ensure!(
                    *straggler == StragglerSpec::None,
                    "run.straggler simulates slow workers in-process; the \
                     network transport gets real stragglers — remove the knob"
                );
                ensure!(
                    *work_multiplier == (1, 1),
                    "run.work_multiplier is an in-process simulation knob; \
                     it does not apply to network workers"
                );
            }
            other => bail!(
                "serve requires the async engine (run.mode=async); engine \
                 `{}` has no delayed-update server loop to host",
                other.name()
            ),
        }
        let instance = ProblemInstance::from_config(problem, cfg)?;
        instance.supports(&spec.engine)?;
        // The same problem-dependent fan-out rule the Runner applies at
        // dispatch (one rule, one implementation).
        runner.check_batch(instance.num_blocks())?;
        // Fail fast on a bad fleet knob — workers would otherwise reject
        // the handshake config one by one.
        let opts = NetOptions::from_config(cfg)?;
        if opts.checkpoint_dir.is_some() {
            // The weighted average x-bar_k is deliberately not part of
            // the checkpoint (it would double the durable state for an
            // option the serve role rarely uses); rather than silently
            // restoring a wrong average, refuse the combination.
            ensure!(
                !spec.weighted_averaging,
                "run.averaging: the weighted iterate average is not \
                 checkpointed — disable it or drop run.checkpoint_dir"
            );
        }
        if opts.shards > 1 {
            ensure!(
                !spec.weighted_averaging,
                "run.averaging: weighted iterate averaging needs the whole \
                 parameter on one host and is incompatible with \
                 run.shards > 1"
            );
            ensure!(
                !spec.exact_gap,
                "run.exact_gap evaluates the whole parameter and is \
                 incompatible with run.shards > 1"
            );
        }
        let (listeners, hosted, plan) =
            Self::bind_plane(&instance, &opts, addr)?;
        let config_pairs = cfg
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        Ok(BoundServer {
            listeners,
            hosted,
            plan,
            spec,
            instance,
            config_pairs,
            opts,
        })
    }

    /// Bind the listener(s) and derive the [`ShardPlan`]. Unsharded: one
    /// listener on `addr`, the trivial plan. Sharded with an explicit
    /// base port: shard `s` listens on `port + s` (every process derives
    /// the same plan from the same config); `run.shard_id` then binds
    /// only its own shard. Sharded with port 0: S ephemeral listeners,
    /// single-process only (the self-hosted loopback mode).
    fn bind_plane(
        instance: &ProblemInstance,
        opts: &NetOptions,
        addr: &str,
    ) -> Result<(Vec<TcpListener>, Vec<usize>, ShardPlan)> {
        if opts.shards == 1 {
            let listener = TcpListener::bind(addr)?;
            let local = listener.local_addr()?.to_string();
            let plan = build_plan(instance, vec![local])?;
            return Ok((vec![listener], vec![0], plan));
        }
        let (host, port_str) = addr.rsplit_once(':').ok_or_else(|| {
            anyhow!("listen address {addr:?} is not host:port")
        })?;
        let port: u16 = port_str
            .parse()
            .map_err(|_| anyhow!("listen address {addr:?} has a bad port"))?;
        if port == 0 {
            ensure!(
                opts.shard_id.is_none(),
                "run.shard_id needs an explicit base port: with port 0 \
                 each process would resolve different ephemeral ports and \
                 the shard plans would disagree"
            );
            let mut listeners = Vec::with_capacity(opts.shards);
            let mut addrs = Vec::with_capacity(opts.shards);
            for _ in 0..opts.shards {
                let l = TcpListener::bind((host, 0))?;
                addrs.push(l.local_addr()?.to_string());
                listeners.push(l);
            }
            let plan = build_plan(instance, addrs)?;
            return Ok((listeners, (0..opts.shards).collect(), plan));
        }
        ensure!(
            port as usize + opts.shards - 1 <= u16::MAX as usize,
            "base port {port} + run.shards = {} overflows the port range",
            opts.shards
        );
        let addrs: Vec<String> = (0..opts.shards)
            .map(|s| format!("{host}:{}", port + s as u16))
            .collect();
        let hosted: Vec<usize> = match opts.shard_id {
            Some(i) => vec![i],
            None => (0..opts.shards).collect(),
        };
        let mut listeners = Vec::with_capacity(hosted.len());
        for &s in &hosted {
            listeners.push(TcpListener::bind(addrs[s].as_str())?);
        }
        let plan = build_plan(instance, addrs)?;
        Ok((listeners, hosted, plan))
    }

    /// The bound listen address of the first hosted shard (resolves port
    /// 0 to the ephemeral port). Workers dial this; a sharded session's
    /// remaining addresses travel in the handshake's [`ShardPlan`].
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listeners[0].local_addr()?)
    }

    /// The session's block→shard plan (trivial at `run.shards = 1`).
    pub fn shard_plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Accept the expected worker fleet, run the delayed-update serve
    /// loop(s) to completion, and return the unified [`Report`] (engine
    /// name `"net"`). Live events stream to `obs` exactly as for the
    /// in-process engines; a sharded run streams per-shard applies from
    /// shard loops into their own cores and reports the aggregated final
    /// sample.
    pub fn run(self, obs: &mut dyn Observer) -> Result<Report> {
        match &self.instance {
            ProblemInstance::Gfl(p) => self.run_plan(p, obs),
            ProblemInstance::Qp(p) => self.run_plan(p, obs),
            ProblemInstance::Chain(p) => self.run_plan(p, obs),
            ProblemInstance::Multiclass(p) => self.run_plan(p, obs),
        }
    }

    /// The thin rendezvous over the hosted shard loops: the single-shard
    /// plan takes the historical one-loop path unchanged; a sharded plan
    /// runs one loop per hosted shard under a shared global-stop flag
    /// (any shard finishing — budget, target, failure — stops the
    /// plane), then folds the per-shard results into one [`Report`] via
    /// [`shard::aggregate`].
    fn run_plan<P: Problem>(
        &self,
        problem: &P,
        obs: &mut dyn Observer,
    ) -> Result<Report> {
        if self.plan.is_single() {
            let rr =
                self.run_shard(problem, 0, &self.listeners[0], None, obs)?;
            return Ok(Report::from_run("net", rr));
        }
        let global_stop = AtomicBool::new(false);
        let mut results: Vec<(usize, RunResult)> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .hosted
                .iter()
                .zip(&self.listeners)
                .map(|(&s, listener)| {
                    let global_stop = &global_stop;
                    scope.spawn(move || {
                        let r = self.run_shard(
                            problem,
                            s,
                            listener,
                            Some(global_stop),
                            &mut (),
                        );
                        // Whatever ended this shard — including an error
                        // before its loop started — ends the plane.
                        global_stop.store(true, Ordering::Release);
                        (s, r)
                    })
                })
                .collect();
            for h in handles {
                match h.join() {
                    Ok((s, Ok(rr))) => results.push((s, rr)),
                    Ok((_, Err(e))) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                    Err(_) => {
                        if first_err.is_none() {
                            first_err =
                                Some(anyhow!("shard serve loop panicked"));
                        }
                    }
                }
            }
        });
        if let Some(e) = first_err {
            return Err(e);
        }
        results.sort_by_key(|(s, _)| *s);
        let hosted: Vec<usize> = results.iter().map(|(s, _)| *s).collect();
        let per_shard: Vec<RunResult> =
            results.into_iter().map(|(_, r)| r).collect();
        let rr = shard::aggregate(problem, &self.plan, &hosted, per_shard);
        if let Some(last) = rr.trace.last() {
            obs.on_sample(last);
        }
        Ok(Report::from_run("net", rr))
    }

    /// The handshake frame shard `shard` issues to worker `worker_id` —
    /// identical for the initial fleet and mid-run joiners, and carrying
    /// the session's whole [`ShardPlan`] so the worker can route.
    /// `generation` is the shard's current session generation (v5): the
    /// worker stamps every Update frame for this shard with it, and the
    /// apply core fences anything else. `resume_draws` is nonzero only
    /// in a restored session's initial-fleet handshake: the number of
    /// block-sampling draws the worker discards to realign its rng with
    /// the pre-crash run (exact for the deterministic one-worker
    /// lockstep, best-effort beyond).
    fn make_hello(
        &self,
        worker_id: u32,
        shard: usize,
        generation: u64,
        resume_draws: u64,
    ) -> Msg {
        Msg::Hello(Hello {
            worker_id,
            seed: self.spec.seed,
            tau: self.spec.tau as u32,
            batch: self.spec.batch as u32,
            payload_mode: payload_mode_tag(self.spec.payload),
            n_blocks: self.instance.num_blocks() as u32,
            problem: registry_name(&self.instance).to_string(),
            config: self.config_pairs.clone(),
            shard: shard as u32,
            plan: self.plan.clone(),
            generation,
            resume_draws,
        })
    }

    /// Accept `workers` connections on `listener` (within the
    /// configurable `run.accept_timeout_secs` deadline) and complete the
    /// handshake on each in accept order — the accept index is the
    /// worker id this shard knows the connection by.
    fn accept_fleet(
        &self,
        listener: &TcpListener,
        shard: usize,
        counters: &Counters,
        generation: u64,
        resume_draws: u64,
    ) -> Result<Vec<TcpStream>> {
        let workers = self.spec.engine.workers();
        listener.set_nonblocking(true)?;
        let deadline = Instant::now() + self.opts.accept_timeout;
        let mut conns: Vec<TcpStream> = Vec::with_capacity(workers);
        while conns.len() < workers {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    stream.set_nodelay(true).ok();
                    stream.set_nonblocking(false)?;
                    conns.push(stream);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "shard {shard}: timed out waiting for {workers} \
                             worker connections ({} connected)",
                            conns.len()
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        let mut ebuf = Vec::new();
        for (id, stream) in conns.iter_mut().enumerate() {
            let hello =
                self.make_hello(id as u32, shard, generation, resume_draws);
            let n = wire::write_frame(stream, &hello, &mut ebuf)?;
            Counters::add(&counters.wire_tx_bytes, n as u64);
        }
        Ok(conns)
    }

    /// One shard's crash-recoverable serve loop: run serve *sessions*
    /// until one finishes the solve. A fresh shard starts at generation
    /// 0 (restoring a valid same-run checkpoint when
    /// `run.checkpoint_dir` holds one — auto-restore; `run.restore`
    /// makes the intent explicit); an injected `run.chaos = crash:K`
    /// abort re-enters with the latest durable checkpoint and the next
    /// generation, exactly like killing and restarting the process.
    /// Restore is never load-bearing for liveness: any unusable
    /// checkpoint logs a fresh start.
    fn run_shard<P: Problem>(
        &self,
        problem: &P,
        shard: usize,
        listener: &TcpListener,
        global_stop: Option<&AtomicBool>,
        obs: &mut dyn Observer,
    ) -> Result<RunResult> {
        let ckpt_dir = self.opts.checkpoint_dir.as_deref().map(PathBuf::from);
        let fp = checkpoint::fingerprint(&self.config_pairs, &self.plan);
        let mut restored = ckpt_dir
            .as_deref()
            .and_then(|d| checkpoint::load_for_restore(d, shard, fp));
        if self.opts.restore && restored.is_none() {
            eprintln!(
                "[serve] shard {shard}: --restore requested but no usable \
                 checkpoint found; starting fresh"
            );
        }
        let mut generation = match &restored {
            Some(ck) => ck.generation + 1,
            None => 0,
        };
        loop {
            let end = self.run_shard_session(
                problem,
                shard,
                listener,
                global_stop,
                obs,
                restored.take(),
                generation,
                fp,
                ckpt_dir.as_deref(),
            )?;
            match end {
                SessionEnd::Finished(rr) => return Ok(*rr),
                SessionEnd::Crashed => {
                    eprintln!(
                        "[serve] shard {shard}: injected crash \
                         (run.chaos crash) at generation {generation}; \
                         restarting with restore"
                    );
                    restored = ckpt_dir
                        .as_deref()
                        .and_then(|d| checkpoint::load_for_restore(d, shard, fp));
                    // Even without a durable checkpoint the restarted
                    // session must advance the generation: the crash op
                    // fires only at generation 0, and any pre-crash
                    // in-flight update must stay fenced.
                    generation = match &restored {
                        Some(ck) => ck.generation + 1,
                        None => generation + 1,
                    };
                }
            }
        }
    }

    /// One serve *session* of shard `shard`: own the plan's block range
    /// and parameter span, feed decoded wire updates into a dedicated
    /// [`ApplyCore`] (fencing generations other than `generation`),
    /// answer span-scoped snapshot pulls, manage this shard's slice of
    /// the fleet, and write durable checkpoints every
    /// `run.checkpoint_every` applied updates into `ckpt_dir`. With
    /// checkpointing off and no `resume`, the generation-0 call (`shard
    /// = 0`, no global stop) is the whole historical server, bit for
    /// bit.
    #[allow(clippy::too_many_arguments)]
    fn run_shard_session<P: Problem>(
        &self,
        problem: &P,
        shard: usize,
        listener: &TcpListener,
        global_stop: Option<&AtomicBool>,
        obs: &mut dyn Observer,
        resume: Option<Checkpoint>,
        generation: u64,
        fingerprint: u64,
        ckpt_dir: Option<&Path>,
    ) -> Result<SessionEnd> {
        let spec = &self.spec;
        let (staleness_rule, collision_overwrite, queue_factor) =
            match &spec.engine {
                Engine::Async {
                    staleness_rule,
                    collision_overwrite,
                    queue_factor,
                    ..
                } => (*staleness_rule, *collision_overwrite, *queue_factor),
                _ => unreachable!("bind() accepts only the async engine"),
            };
        let workers = spec.engine.workers();
        // Whether workers run the self-tuning fan-out controller — the
        // gate on the `batch_resizes` payload-length tracking below.
        let adapt_batch = self.spec.adapt.batch
            != crate::sim::adapt::BatchPolicy::Off;
        let n = problem.num_blocks();
        let s_count = self.plan.len();
        let owned = self.plan.block_range(shard);
        let owned_n = owned.len();
        let span = self.plan.param_span(shard);
        // Per-shard minibatch: the global tau split evenly across the
        // plane (each shard sees ~1/S of the update stream), floored at
        // 1. The unsharded call keeps spec.tau exactly.
        let tau = if s_count == 1 {
            spec.tau
        } else {
            (spec.tau / s_count).max(1)
        }
        .clamp(1, owned_n);
        let batch_eff = spec.batch.clamp(1, n);
        // Blocks of one fan-out round this shard expects back: a worker
        // samples `batch_eff` blocks globally, of which this shard owns
        // `owned_n / n` in expectation (ceiling, so a dead worker's
        // requeue telemetry never undercounts). `batch_eff` exactly at
        // one shard.
        let quota = if s_count == 1 {
            batch_eff
        } else {
            (batch_eff * owned_n).div_ceil(n).max(1)
        };
        let mut stop = spec.stop;
        if s_count > 1 {
            // A shard sees only its share of the oracle stream: scale
            // the epoch budget by the owned-block fraction so S shards
            // together spend the spec's global budget. Objective/gap
            // targets are global quantities a single shard cannot
            // evaluate — the rendezvous evaluates them on the assembled
            // iterate instead.
            stop.max_epochs = stop.max_epochs * owned_n as f64 / n as f64;
            stop.f_star = None;
            stop.eps_primal = None;
            stop.eps_gap = None;
        }
        let counters = Counters::new();
        // Rng realignment for a restored session's initial fleet: how
        // many block-sampling rounds the pre-crash run consumed. One
        // `pick_blocks` call per ingested frame, and every ingested
        // frame's `batch_eff` oracles were either applied or dropped —
        // so the checkpointed counters give the round count exactly in
        // the deterministic one-worker lockstep (and a best-effort
        // realignment beyond, where bit-reproducibility never held).
        let resume_draws = resume.as_ref().map_or(0, |ck| {
            (ck.counters.updates_applied + ck.counters.dropped)
                / batch_eff as u64
        });
        // Millisecond origin for the per-connection last-seen stamps.
        let epoch = Instant::now();
        let mut conns: Vec<ConnState> = self
            .accept_fleet(listener, shard, &counters, generation, resume_draws)?
            .into_iter()
            .enumerate()
            .map(|(id, stream)| ConnState {
                stream: Some(stream),
                worker_id: id as u32,
                // Stamped "now", not 0: accepting the fleet may itself
                // take a while, and a worker must get a full liveness
                // window from handshake, not from the epoch.
                last_seen: Arc::new(AtomicU64::new(
                    epoch.elapsed().as_millis() as u64,
                )),
                outstanding: 0,
                last_batch: None,
            })
            .collect();
        // Mid-run joiners get ids above the initial fleet — an id is
        // never recycled, so assembler keys stay unique per shard across
        // the whole run.
        let mut next_worker_id = conns.len() as u32;

        let mut core = ApplyCore::new(
            problem,
            ApplyKnobs {
                tau,
                line_search: spec.line_search,
                staleness_rule,
                collision_overwrite,
                sample_every: spec.sample_every,
                exact_gap: spec.exact_gap,
                weighted_averaging: spec.weighted_averaging,
                adapt_step: spec.adapt.step,
                adapt_drop: spec.adapt.drop,
                stop,
                iter_scale: s_count as u64,
            },
            &counters,
        );
        if let Some(ck) = resume {
            if ck.master.len() != core.master().len() {
                eprintln!(
                    "[serve] shard {shard}: checkpoint master has {} \
                     entries (expected {}); starting fresh",
                    ck.master.len(),
                    core.master().len()
                );
            } else if let Err(e) = problem
                .restore_server_state(core.server_state_mut(), &ck.server_state)
            {
                eprintln!(
                    "[serve] shard {shard}: checkpoint server state is \
                     unusable ({e:#}); starting fresh"
                );
            } else {
                // Pre-load the whole-run telemetry, then resume the core
                // at the checkpointed iteration under the new
                // generation; every pre-crash in-flight update is now
                // fence-dead on arrival.
                counters.absorb(&ck.counters);
                let trace = ck.trace();
                core.resume(
                    ck.k,
                    ck.master,
                    ck.gap_estimate,
                    trace,
                    generation,
                );
                Counters::bump(&counters.restores);
                eprintln!(
                    "[serve] shard {shard}: restored checkpoint \
                     (k = {}, generation {generation})",
                    core.k()
                );
            }
        }
        // Durable-checkpoint cadence: the next applied-update count at
        // which a checkpoint is due. `u64::MAX` with the knob off keeps
        // the default serve loop checkpoint-free (and byte-identical to
        // the v4 fleet).
        let ckpt_every = self.opts.checkpoint_every;
        let mut next_ckpt = if ckpt_every > 0 {
            (core.k() / ckpt_every + 1) * ckpt_every
        } else {
            u64::MAX
        };
        // Instance-level frame validation bound: payload dimensions are
        // block-independent for every registered problem, so one probe
        // oracle fixes the dimension every wire update must carry. The
        // codec checks only a frame's self-consistency; this is what
        // keeps a codec-valid but malformed frame (config drift, hostile
        // peer) out of the apply path.
        let payload_dim = problem.oracle(core.master(), owned.start).s.dim();
        // Dirty ranges per applied version, newest at the back (`None` =
        // a full-parameter write, e.g. SSVM's dense w update).
        let mut delta_log: VecDeque<(u64, DirtyRanges)> =
            VecDeque::with_capacity(DELTA_LOG_CAP);

        // Each worker has at most one request in flight (the protocol
        // strictly alternates), so 2 slots per worker never blocks a
        // reader; the queue_factor headroom mirrors the in-process
        // engine's backpressure depth.
        let queue_cap = (queue_factor.max(1) * tau).max(2 * workers);
        let (tx, rx) = mpsc::sync_channel::<Event>(queue_cap);
        let mut ebuf: Vec<u8> = Vec::new();

        // Clone the read halves before spawning anything: once a reader
        // thread exists, this function must reach the shutdown sequence
        // (which unblocks readers) before returning, so no fallible work
        // is allowed inside the scope.
        let mut reader_streams: Vec<TcpStream> =
            Vec::with_capacity(conns.len());
        for c in conns.iter() {
            reader_streams.push(
                c.stream
                    .as_ref()
                    .expect("all connections start alive")
                    .try_clone()?,
            );
        }

        // Set when the injected `crash:K` fires: the session then skips
        // the orderly shutdown (no Shutdown frames, no global stop) and
        // the caller restarts it from the durable checkpoint.
        let mut crashed = false;
        std::thread::scope(|scope| {
            // ---------------- connection readers ----------------
            for (conn, reader) in reader_streams.into_iter().enumerate() {
                let tx = tx.clone();
                let counters = &counters;
                let last_seen = Arc::clone(&conns[conn].last_seen);
                scope.spawn(move || {
                    read_loop(conn, reader, tx, counters, last_seen, epoch)
                });
            }
            // `tx` stays alive here: mid-run joiners need fresh clones.

            // ---------------- serve loop ----------------
            // One deadline-aware wait per turn: the loop blocks on the
            // event channel until the earliest of (accept poll, liveness
            // scan) is due — no 2 ms busy-spin, yet update ingestion
            // still wakes it immediately.
            let mut alive = conns.len();
            let mut next_accept = Instant::now() + ACCEPT_POLL;
            let liveness_period = self
                .opts
                .liveness
                .map(|d| (d / 4).max(Duration::from_millis(1)));
            let mut next_liveness =
                liveness_period.map(|p| Instant::now() + p);
            // When the whole fleet is gone, wait this grace window (the
            // accept deadline again) for a rejoin before giving up —
            // a crashed-and-restarting worker must not kill the run.
            let mut empty_since: Option<Instant> = None;
            'serve: loop {
                // A sibling shard ended the run (its budget, a target on
                // the assembled iterate, or a failure): stop before
                // touching the event queue so fleet telemetry stays
                // deterministic across shards.
                if global_stop.is_some_and(|s| s.load(Ordering::Acquire)) {
                    break 'serve;
                }
                let now = Instant::now();

                // -- accept mid-run joiners (nonblocking poll) --
                if now >= next_accept {
                    next_accept = now + ACCEPT_POLL;
                    while let Ok((stream, _peer)) = listener.accept() {
                        stream.set_nodelay(true).ok();
                        if stream.set_nonblocking(false).is_err() {
                            continue;
                        }
                        let mut stream = stream;
                        let worker_id = next_worker_id;
                        // Joiners never fast-forward: their fresh worker
                        // id selects an rng stream no pre-crash session
                        // ever drew from.
                        let hello =
                            self.make_hello(worker_id, shard, generation, 0);
                        // A joiner lost mid-handshake is simply dropped —
                        // nothing fallible may escape this scope.
                        let nb = match wire::write_frame(
                            &mut stream,
                            &hello,
                            &mut ebuf,
                        ) {
                            Ok(nb) => nb,
                            Err(_) => continue,
                        };
                        let reader = match stream.try_clone() {
                            Ok(r) => r,
                            Err(_) => continue,
                        };
                        Counters::add(&counters.wire_tx_bytes, nb as u64);
                        next_worker_id += 1;
                        let last_seen = Arc::new(AtomicU64::new(
                            epoch.elapsed().as_millis() as u64,
                        ));
                        let conn = conns.len();
                        conns.push(ConnState {
                            stream: Some(stream),
                            worker_id,
                            last_seen: Arc::clone(&last_seen),
                            outstanding: 0,
                            last_batch: None,
                        });
                        let tx = tx.clone();
                        let counters = &counters;
                        scope.spawn(move || {
                            read_loop(
                                conn, reader, tx, counters, last_seen, epoch,
                            )
                        });
                        alive += 1;
                        empty_since = None;
                        Counters::bump(&counters.workers_joined);
                    }
                }

                // -- liveness scan: reap silent connections --
                if let (Some(window), Some(period)) =
                    (self.opts.liveness, liveness_period)
                {
                    if next_liveness.is_some_and(|t| now >= t) {
                        next_liveness = Some(now + period);
                        let now_ms = epoch.elapsed().as_millis() as u64;
                        let cutoff = window.as_millis() as u64;
                        for i in 0..conns.len() {
                            let silent_ms = now_ms.saturating_sub(
                                conns[i].last_seen.load(Ordering::Relaxed),
                            );
                            if conns[i].stream.is_some() && silent_ms > cutoff
                            {
                                kill_conn(
                                    &mut conns, i, &mut alive, &mut core,
                                    &counters,
                                );
                            }
                        }
                    }
                }

                // -- empty-fleet grace --
                if alive == 0 {
                    match empty_since {
                        None => empty_since = Some(now),
                        Some(t0)
                            if now.duration_since(t0)
                                >= self.opts.accept_timeout =>
                        {
                            break 'serve;
                        }
                        Some(_) => {}
                    }
                } else {
                    empty_since = None;
                }

                // -- deadline-aware event wait --
                let mut deadline = next_accept;
                if let Some(t) = next_liveness {
                    deadline = deadline.min(t);
                }
                let wait =
                    deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(wait) {
                    Ok(Event::Update { conn, msg }) => {
                        // Reject oracles this shard cannot apply (block
                        // outside the owned range, payload of the wrong
                        // dimension) and kill the connection — a protocol
                        // violation, not a recoverable update. The later
                        // `Gone` from its reader is then a no-op. An
                        // EMPTY payload is valid: a sharded worker whose
                        // round sampled no blocks of this shard still
                        // completes its request/response alternation.
                        let valid = msg.oracles.iter().all(|o| {
                            owned.contains(&o.block)
                                && o.s.dim() == payload_dim
                        });
                        if !valid {
                            kill_conn(
                                &mut conns, conn, &mut alive, &mut core,
                                &counters,
                            );
                            continue;
                        }
                        // The outstanding fan-out round came back.
                        conns[conn].outstanding = 0;
                        // Under the adaptive batch controller, a payload
                        // length transition is a worker-side resize.
                        if adapt_batch && !msg.oracles.is_empty() {
                            let len = msg.oracles.len();
                            if conns[conn]
                                .last_batch
                                .is_some_and(|prev| prev != len)
                            {
                                Counters::bump(&counters.batch_resizes);
                            }
                            conns[conn].last_batch = Some(len);
                        }
                        // In-process engines count oracle calls at the
                        // worker's solve site; on the wire the receipt
                        // is the first place the server sees them.
                        Counters::add(
                            &counters.oracle_calls,
                            msg.oracles.len() as u64,
                        );
                        // Payload telemetry, the k/2 staleness verdict
                        // and buffering all live in the shared core.
                        core.ingest(msg, &|_| {});
                    }
                    Ok(Event::SnapReq { conn, have }) => {
                        let body = snapshot_body(
                            core.master(),
                            &span,
                            &delta_log,
                            core.k(),
                            have,
                        );
                        let msg = Msg::Snapshot {
                            version: core.k(),
                            body,
                        };
                        // The snapshot answer is the serve role's one
                        // mode-aware write: under f16/q8 the body ships
                        // in the compressed (still lossless) v4 layouts.
                        let sent = match &mut conns[conn].stream {
                            Some(stream) => wire::write_frame_mode(
                                stream,
                                &msg,
                                &mut ebuf,
                                self.opts.wire,
                            ),
                            None => continue, // already declared dead
                        };
                        match sent {
                            Ok(nb) => {
                                Counters::add(
                                    &counters.wire_tx_bytes,
                                    nb as u64,
                                );
                                Counters::bump(&counters.snapshot_reads);
                                // The worker now owes this shard its
                                // share of one fan-out round.
                                conns[conn].outstanding = quota;
                            }
                            // kill_conn shuts the socket down before
                            // dropping our clone: the reader thread holds
                            // its own dup and would otherwise block in
                            // read forever (scope would never join).
                            Err(_) => kill_conn(
                                &mut conns, conn, &mut alive, &mut core,
                                &counters,
                            ),
                        }
                    }
                    Ok(Event::Gone { conn }) => {
                        kill_conn(
                            &mut conns, conn, &mut alive, &mut core,
                            &counters,
                        );
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => break 'serve,
                }

                // Drain every ready tau-batch through the shared apply
                // core; the publish hook records the dirty ranges this
                // transport needs for its snapshot deltas. The hook is
                // built inline so its borrow of the delta log ends with
                // the call (the SnapReq arm reads the log too).
                if core.drain(
                    &mut *obs,
                    &mut |kk: u64,
                          _master: &[f32],
                          ranges: DirtyRanges,
                          _batch: Vec<BlockOracle>| {
                        if delta_log.len() == DELTA_LOG_CAP {
                            delta_log.pop_front();
                        }
                        delta_log.push_back((kk, ranges));
                    },
                ) {
                    break 'serve;
                }

                // -- durable checkpoint cadence --
                if core.k() >= next_ckpt {
                    next_ckpt = (core.k() / ckpt_every + 1) * ckpt_every;
                    let ck = Checkpoint {
                        fingerprint,
                        shard: shard as u32,
                        generation,
                        k: core.k(),
                        gap_estimate: core.gap_estimate(),
                        master: core.master().to_vec(),
                        samples: core.trace().samples.clone(),
                        counters: counters.snapshot(),
                        server_state: problem
                            .checkpoint_server_state(core.server_state()),
                    };
                    // The dir is guaranteed here: NetOptions validation
                    // ties checkpoint_every > 0 to checkpoint_dir.
                    let dir = ckpt_dir
                        .expect("checkpoint_every > 0 implies a dir");
                    match ck.write_atomic(dir) {
                        Ok(()) => {
                            Counters::bump(&counters.checkpoints_written)
                        }
                        // A full or failing disk must degrade the
                        // durability guarantee, not the solve.
                        Err(e) => eprintln!(
                            "[serve] shard {shard}: checkpoint write \
                             failed ({e:#}); continuing without it"
                        ),
                    }
                }

                // -- injected deterministic crash (generation 0 only,
                // so a restored session can never re-crash) --
                if generation == 0 {
                    if let Some(crash_k) = self.opts.chaos.crash {
                        if core.k() >= crash_k {
                            crashed = true;
                            break 'serve;
                        }
                    }
                }

                // Budget check even while starved of updates.
                if core.budget_exhausted() {
                    break 'serve;
                }
            }

            if crashed {
                // Abrupt crash: NO Shutdown frames and NO global stop —
                // workers see a dead socket mid-protocol (exactly what a
                // killed serve process looks like) and reconnect with
                // backoff into the restarted session; sibling shards
                // keep running. In-flight updates die with the socket,
                // and any that were already decoded are fence-dead under
                // the restarted generation.
                for stream in
                    conns.iter_mut().filter_map(|c| c.stream.as_mut())
                {
                    stream.shutdown(std::net::Shutdown::Both).ok();
                }
            } else {
                // Raise the plane-wide stop BEFORE telling workers: a
                // worker reacting to this shard's Shutdown must find its
                // sibling shards already stopping, not still mid-loop.
                if let Some(s) = global_stop {
                    s.store(true, Ordering::Release);
                }
                // Orderly shutdown: tell every live worker, then close
                // both socket halves so blocked reader threads unblock
                // and exit.
                for stream in
                    conns.iter_mut().filter_map(|c| c.stream.as_mut())
                {
                    if let Ok(nb) =
                        wire::write_frame(stream, &Msg::Shutdown, &mut ebuf)
                    {
                        Counters::add(&counters.wire_tx_bytes, nb as u64);
                    }
                    stream.shutdown(std::net::Shutdown::Both).ok();
                }
            }
            // Dropping the receiver errors out any reader still sending,
            // so blocked backpressure sends cannot outlive the loop.
            drop(tx);
            drop(rx);
        });

        if crashed {
            return Ok(SessionEnd::Crashed);
        }
        Ok(SessionEnd::Finished(Box::new(core.finish(obs))))
    }
}

/// Decode frames off one connection into the server's event channel,
/// stamping `last_seen` (ms since `epoch`) on every decoded frame.
/// Heartbeats and join announcements are absorbed right here — they
/// refresh liveness (and the `reconnects` counter) without ever entering
/// the loop's event ordering, which is part of what keeps the fixed-fleet
/// path bit-identical to v1. Exits on any read error, a clean close, a
/// protocol violation, or a hung-up server loop — always announcing
/// `Gone` (best-effort) first.
///
/// Backpressure: a full event channel is counted (`event_stalls`, logged
/// on first occurrence) and then waited out with a blocking send — a slow
/// consumer stalls readers instead of growing an unbounded buffer, and
/// nothing panics.
fn read_loop(
    conn: usize,
    mut stream: TcpStream,
    tx: mpsc::SyncSender<Event>,
    counters: &Counters,
    last_seen: Arc<AtomicU64>,
    epoch: Instant,
) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some((msg, nbytes))) => {
                Counters::add(&counters.wire_rx_bytes, nbytes as u64);
                last_seen.store(
                    epoch.elapsed().as_millis() as u64,
                    Ordering::Relaxed,
                );
                let event = match msg {
                    Msg::Update {
                        k_read,
                        worker,
                        generation,
                        oracles,
                    } => {
                        // Update-frame bytes as actually shipped (after
                        // any v4 quantization) — the transport-side
                        // counterpart of the logical `payload_bytes`
                        // that `ApplyCore::ingest` counts at receipt.
                        Counters::add(
                            &counters.shipped_payload_bytes,
                            nbytes as u64,
                        );
                        Event::Update {
                            conn,
                            msg: UpdateMsg {
                                oracles,
                                k_read,
                                worker: worker as usize,
                                // The v5 generation stamp rides through
                                // to ApplyCore::ingest's fence.
                                generation,
                            },
                        }
                    }
                    Msg::SnapshotRequest { have_version } => Event::SnapReq {
                        conn,
                        have: have_version,
                    },
                    Msg::Heartbeat => continue,
                    Msg::Join { resumed } => {
                        if resumed {
                            Counters::bump(&counters.reconnects);
                        }
                        continue;
                    }
                    // Anything else from a worker is a protocol violation;
                    // drop the connection.
                    _ => break,
                };
                match tx.try_send(event) {
                    Ok(()) => {}
                    Err(mpsc::TrySendError::Full(event)) => {
                        if counters
                            .event_stalls
                            .fetch_add(1, Ordering::Relaxed)
                            == 0
                        {
                            eprintln!(
                                "[serve] event channel full; reader {conn} \
                                 applying backpressure"
                            );
                        }
                        if tx.send(event).is_err() {
                            return; // server loop is gone
                        }
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => return,
                }
            }
            Ok(None) | Err(_) => break,
        }
    }
    tx.send(Event::Gone { conn }).ok();
}

/// Build the snapshot body for a worker holding `have`: an empty delta if
/// it is current, a dirty-range delta when the log covers the gap (and it
/// is actually smaller than this shard's owned `span`), a span resync
/// otherwise. A resync from the span-owning-everything server is a
/// [`SnapshotBody::Full`] — bit-identical to the unsharded v2 answer —
/// while a shard resync is a single-run delta covering the span (a
/// sharded worker initializes its parameter locally and splices every
/// shard's answer into it).
fn snapshot_body(
    master: &[f32],
    span: &Range<usize>,
    log: &VecDeque<(u64, DirtyRanges)>,
    k: u64,
    have: u64,
) -> SnapshotBody {
    let full_span = || {
        if span.start == 0 && span.end == master.len() {
            SnapshotBody::Full(master.to_vec())
        } else {
            SnapshotBody::Delta(vec![(
                span.start as u32,
                master[span.clone()].to_vec(),
            )])
        }
    };
    if have == k {
        return SnapshotBody::Delta(Vec::new());
    }
    if have > k {
        // `u64::MAX` sentinel (nothing held) or a confused peer: resync.
        return full_span();
    }
    // The log entry for version v records the ranges dirtied by the
    // apply that *produced* v, so a worker at `have` needs entries
    // `have+1..=k` — covered iff the oldest retained entry is at most
    // `have + 1`. Saturating: `have = u64::MAX` is the nothing-held
    // sentinel (already resynced above), but the guard keeps this
    // expression structurally panic-free either way.
    let covered = log
        .front()
        .map(|(oldest, _)| *oldest <= have.saturating_add(1))
        .unwrap_or(false);
    if covered {
        let mut ranges: Vec<Range<usize>> = Vec::new();
        let mut full = false;
        for (v, r) in log.iter() {
            if *v <= have {
                continue;
            }
            match r {
                Some(rs) => ranges.extend(rs.iter().cloned()),
                None => {
                    full = true;
                    break;
                }
            }
        }
        if !full {
            let merged = merge_ranges(ranges);
            let total: usize = merged.iter().map(|r| r.len()).sum();
            if total < span.len() {
                let runs = merged
                    .iter()
                    .map(|r| (r.start as u32, master[r.clone()].to_vec()))
                    .collect();
                return SnapshotBody::Delta(runs);
            }
        }
    }
    full_span()
}

/// The registry name a worker passes back to
/// [`ProblemInstance::from_config`] (the CLI `solve` vocabulary, not the
/// inner problem's display name).
fn registry_name(instance: &ProblemInstance) -> &'static str {
    match instance {
        ProblemInstance::Gfl(_) => "gfl",
        ProblemInstance::Qp(_) => "qp",
        ProblemInstance::Chain(_) => "ssvm",
        ProblemInstance::Multiclass(_) => "multiclass",
    }
}

/// Bind on `addr`, accept the spec's worker fleet, and run the solve to
/// completion — the CLI `apbcfw serve` entry point.
pub fn serve(
    spec: RunSpec,
    problem: &str,
    cfg: &Config,
    addr: &str,
    obs: &mut dyn Observer,
) -> Result<Report> {
    BoundServer::bind(spec, problem, cfg, addr)?.run(obs)
}

/// Self-hosted loopback mode: bind on `addr` (use port 0 for an ephemeral
/// port — with `run.shards > 1` every shard resolves its own), spawn the
/// spec's worker fleet as in-process threads that connect back over real
/// TCP (127.0.0.1), and run the solve — one process, but every oracle
/// payload crosses the wire codec. This is the mode the
/// distributed==in-process equivalence tests pin.
pub fn solve_loopback(
    spec: RunSpec,
    problem: &str,
    cfg: &Config,
    addr: &str,
) -> Result<Report> {
    let workers = spec.engine.workers();
    let server = BoundServer::bind(spec, problem, cfg, addr)?;
    let bound = server.local_addr()?;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            // Resilient workers: under `run.chaos` an injected disconnect
            // mid-run is survived by reconnecting (the server's listener
            // stays open for joiners); once the run ends and the listener
            // drops, a reconnect attempt is refused and the worker exits
            // with its summed summary. Without chaos this is exactly the
            // single-session worker. A sharded worker dials shard 0 here
            // and learns its siblings from the handshake plan.
            handles.push(scope.spawn(move || {
                super::worker::run_resilient(
                    &bound.to_string(),
                    Duration::from_secs(10),
                )
            }));
        }
        let report = server.run(&mut ())?;
        for h in handles {
            h.join()
                .map_err(|_| anyhow!("loopback worker thread panicked"))??;
        }
        Ok(report)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Config {
        Config::parse("[gfl]\nd = 4\nn = 20\n").unwrap()
    }

    #[test]
    fn bind_rejects_non_async_engines() {
        let spec = RunSpec::new(Engine::sequential());
        let err = BoundServer::bind(spec, "gfl", &cfg(), "127.0.0.1:0")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("async"), "{err}");
    }

    #[test]
    fn bind_rejects_simulation_knobs() {
        let spec = RunSpec::new(
            Engine::asynchronous(1)
                .with_straggler(StragglerSpec::Single { p: 0.5 }),
        );
        let err = BoundServer::bind(spec, "gfl", &cfg(), "127.0.0.1:0")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("straggler"), "{err}");
        let spec =
            RunSpec::new(Engine::asynchronous(1).with_work_multiplier(2, 5));
        let err = BoundServer::bind(spec, "gfl", &cfg(), "127.0.0.1:0")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("work_multiplier"), "{err}");
    }

    #[test]
    fn bind_rejects_oversized_fanout() {
        // gfl d=4 n=20 -> 19 blocks; 8 x 4 > 19.
        let spec = RunSpec::new(Engine::asynchronous(4)).batch(8);
        let err = BoundServer::bind(spec, "gfl", &cfg(), "127.0.0.1:0")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn bind_rejects_bad_fleet_knobs() {
        for (key, bad, needle) in [
            ("run.chaos", "bogus", "run.chaos"),
            ("run.chaos", "crash:0", "crash"),
            ("run.liveness_ms", "soon", "liveness"),
            ("run.accept_timeout_secs", "0", "accept_timeout"),
            ("run.shards", "0", "run.shards"),
            ("run.shard_id", "0", "run.shard_id"),
            ("run.checkpoint_every", "sometimes", "checkpoint_every"),
            ("run.checkpoint_every", "50", "checkpoint_dir"),
            ("run.restore", "maybe", "run.restore"),
            ("run.restore", "true", "checkpoint_dir"),
        ] {
            let mut c = cfg();
            c.set(key, bad);
            let spec = RunSpec::new(Engine::asynchronous(1));
            let err = BoundServer::bind(spec, "gfl", &c, "127.0.0.1:0")
                .map(|_| ())
                .unwrap_err()
                .to_string();
            assert!(err.contains(needle), "{key}={bad}: {err}");
        }
    }

    #[test]
    fn bind_rejects_weighted_averaging_with_checkpointing() {
        let mut c = cfg();
        c.set("run.checkpoint_dir", "/tmp/apfw-ckpt-unused");
        let spec =
            RunSpec::new(Engine::asynchronous(1)).weighted_averaging(true);
        let err = BoundServer::bind(spec, "gfl", &c, "127.0.0.1:0")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("averag"), "{err}");
        // Without averaging the same knobs bind fine (and binding alone
        // must not create the directory).
        let spec = RunSpec::new(Engine::asynchronous(1));
        BoundServer::bind(spec, "gfl", &c, "127.0.0.1:0").unwrap();
        assert!(!std::path::Path::new("/tmp/apfw-ckpt-unused").exists());
    }

    #[test]
    fn bind_resolves_ephemeral_port() {
        let spec = RunSpec::new(Engine::asynchronous(1));
        let server =
            BoundServer::bind(spec, "gfl", &cfg(), "127.0.0.1:0").unwrap();
        assert_ne!(server.local_addr().unwrap().port(), 0);
    }

    #[test]
    fn bind_sharded_carves_a_plan_over_ephemeral_ports() {
        // gfl d=4 n=20 -> 19 blocks, param_dim 76.
        let mut c = cfg();
        c.set("run.shards", "2");
        let spec = RunSpec::new(Engine::asynchronous(1));
        let server =
            BoundServer::bind(spec, "gfl", &c, "127.0.0.1:0").unwrap();
        let plan = server.shard_plan();
        assert_eq!(plan.len(), 2);
        assert_eq!(server.listeners.len(), 2);
        assert_eq!(server.hosted, vec![0, 1]);
        plan.validate(19, 76).expect("plan tiles the problem");
        // Every listener really is bound where the plan says.
        for (i, l) in server.listeners.iter().enumerate() {
            assert_eq!(
                l.local_addr().unwrap().to_string(),
                plan.get(i).addr
            );
        }
    }

    #[test]
    fn bind_sharded_rejects_whole_parameter_knobs() {
        let mut c = cfg();
        c.set("run.shards", "2");
        let spec =
            RunSpec::new(Engine::asynchronous(1)).weighted_averaging(true);
        let err = BoundServer::bind(spec, "gfl", &c, "127.0.0.1:0")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("averaging"), "{err}");
        let spec = RunSpec::new(Engine::asynchronous(1)).exact_gap(true);
        let err = BoundServer::bind(spec, "gfl", &c, "127.0.0.1:0")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("exact_gap"), "{err}");
    }

    #[test]
    fn bind_shard_id_needs_an_explicit_port() {
        let mut c = cfg();
        c.set("run.shards", "2");
        c.set("run.shard_id", "1");
        let spec = RunSpec::new(Engine::asynchronous(1));
        let err = BoundServer::bind(spec, "gfl", &c, "127.0.0.1:0")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("base port"), "{err}");
    }

    #[test]
    fn snapshot_body_selects_delta_vs_full() {
        let master: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let whole = 0..10usize;
        let mut log = VecDeque::new();
        log.push_back((1u64, Some(vec![0..2usize])));
        log.push_back((2u64, Some(vec![4..6usize])));
        // Current worker: empty delta.
        assert_eq!(
            snapshot_body(&master, &whole, &log, 2, 2),
            SnapshotBody::Delta(Vec::new())
        );
        // One behind: only version 2's ranges.
        assert_eq!(
            snapshot_body(&master, &whole, &log, 2, 1),
            SnapshotBody::Delta(vec![(4, vec![4.0, 5.0])])
        );
        // Two behind: both versions' ranges.
        assert_eq!(
            snapshot_body(&master, &whole, &log, 2, 0),
            SnapshotBody::Delta(vec![
                (0, vec![0.0, 1.0]),
                (4, vec![4.0, 5.0])
            ])
        );
        // Sentinel / uncovered: full.
        assert_eq!(
            snapshot_body(&master, &whole, &log, 2, u64::MAX),
            SnapshotBody::Full(master.clone())
        );
        log.push_back((3u64, None)); // dense write
        assert_eq!(
            snapshot_body(&master, &whole, &log, 3, 2),
            SnapshotBody::Full(master.clone())
        );
    }

    #[test]
    fn snapshot_body_eviction_boundary_is_exact() {
        // The delta-log coverage boundary: entry (v, ranges) records the
        // ranges dirtied by the apply that produced v, so a worker at
        // `have` needs entries have+1..=k. With the oldest retained
        // entry at version `oldest`, `have = oldest - 1` is the LAST
        // covered worker (it needs exactly oldest..=k) and
        // `have = oldest - 2` is the first that must resync — its
        // missing `oldest - 1` entry has been evicted.
        let master: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let whole = 0..10usize;
        let mut log = VecDeque::new();
        for v in 5u64..=8 {
            log.push_back((v, Some(vec![(v as usize - 5)..(v as usize - 3)])));
        }
        // oldest = 5: have = 4 gets a dirty-range delta of all entries.
        assert_eq!(
            snapshot_body(&master, &whole, &log, 8, 4),
            SnapshotBody::Delta(vec![(0, (0..5).map(|i| i as f32).collect())])
        );
        // have = 3 (oldest - 2) missed the evicted version-4 entry: full.
        assert_eq!(
            snapshot_body(&master, &whole, &log, 8, 3),
            SnapshotBody::Full(master.clone())
        );
    }

    #[test]
    fn snapshot_body_covers_across_the_delta_log_cap_eviction() {
        // Fill the log to DELTA_LOG_CAP the way the publish hook does
        // (pop_front at the cap), then check the boundary on the real
        // eviction state: versions 1..=CAP retained after CAP+1 pushes
        // evicted version 0's entry.
        let master: Vec<f32> = vec![1.0; 8];
        let whole = 0..8usize;
        let mut log: VecDeque<(u64, DirtyRanges)> = VecDeque::new();
        for v in 0..=(DELTA_LOG_CAP as u64) {
            if log.len() == DELTA_LOG_CAP {
                log.pop_front();
            }
            log.push_back((v, Some(vec![0..1usize])));
        }
        assert_eq!(log.len(), DELTA_LOG_CAP);
        let (oldest, k) = (log.front().unwrap().0, log.back().unwrap().0);
        assert_eq!((oldest, k), (1, DELTA_LOG_CAP as u64));
        // have = oldest - 1 = 0: still covered (needs 1..=k, all held).
        assert_eq!(
            snapshot_body(&master, &whole, &log, k, oldest - 1),
            SnapshotBody::Delta(vec![(0, vec![1.0])])
        );
        // One more push evicts version 1; the same worker now resyncs.
        if log.len() == DELTA_LOG_CAP {
            log.pop_front();
        }
        log.push_back((k + 1, Some(vec![0..1usize])));
        assert_eq!(
            snapshot_body(&master, &whole, &log, k + 1, 0),
            SnapshotBody::Full(master.clone())
        );
        // ... while have = 1 (the new oldest - 1) stays covered.
        assert_eq!(
            snapshot_body(&master, &whole, &log, k + 1, 1),
            SnapshotBody::Delta(vec![(0, vec![1.0])])
        );
    }

    #[test]
    fn snapshot_body_have_plus_one_cannot_overflow() {
        // `have = u64::MAX` is the nothing-held sentinel and short-
        // circuits into a resync before the coverage check — but the
        // `have + 1` in that check must be structurally overflow-proof
        // (saturating), so probe the largest have that reaches it:
        // have = k - 1 with k = u64::MAX - 1... the sentinel path
        // catches have > k; here we pin both extremes.
        let master: Vec<f32> = vec![2.0; 4];
        let whole = 0..4usize;
        let mut log = VecDeque::new();
        log.push_back((u64::MAX, Some(vec![0..1usize])));
        // Worker one behind a server at k = u64::MAX: covered, delta.
        assert_eq!(
            snapshot_body(&master, &whole, &log, u64::MAX, u64::MAX - 1),
            SnapshotBody::Delta(vec![(0, vec![2.0])])
        );
        // The sentinel itself (have = u64::MAX = k): empty delta.
        assert_eq!(
            snapshot_body(&master, &whole, &log, u64::MAX, u64::MAX),
            SnapshotBody::Delta(Vec::new())
        );
        // And have = u64::MAX against a smaller k: resync, no overflow.
        assert_eq!(
            snapshot_body(&master, &whole, &log, 3, u64::MAX),
            SnapshotBody::Full(master.clone())
        );
    }

    #[test]
    fn snapshot_body_resyncs_a_shard_as_a_span_delta() {
        // A shard owning 4..10 of a 10-wide master never ships Full: its
        // resync is a single-run delta covering exactly the span.
        let master: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let span = 4..10usize;
        let log: VecDeque<(u64, DirtyRanges)> = VecDeque::new();
        assert_eq!(
            snapshot_body(&master, &span, &log, 3, u64::MAX),
            SnapshotBody::Delta(vec![(
                4,
                vec![4.0, 5.0, 6.0, 7.0, 8.0, 9.0]
            )])
        );
        // Covered gap: still the ordinary dirty-range delta.
        let mut log = VecDeque::new();
        log.push_back((1u64, Some(vec![5..7usize])));
        assert_eq!(
            snapshot_body(&master, &span, &log, 1, 0),
            SnapshotBody::Delta(vec![(5, vec![5.0, 6.0])])
        );
    }
}
