//! The versioned, length-prefixed binary wire codec.
//!
//! Hand-rolled little-endian encoding over any `Read`/`Write` pair (a
//! `TcpStream` in production, a `Vec<u8>` cursor in the round-trip tests).
//! The normative protocol specification — frame layout, message table,
//! version and endianness rules, payload encodings, forward-compatibility
//! notes — lives in `docs/WIRE.md`; this module is its reference
//! implementation and must stay in sync with it.
//!
//! The design constraint that shapes everything here: a sparse
//! [`OraclePayload`] is encoded as its `(idx, val, dim)` triple and decoded
//! back into the same variant, so payload sparsity survives the wire
//! end-to-end — the decoder never densifies (pinned by the codec tests in
//! `rust/tests/net_transport.rs`).

use super::shard::{ShardInfo, ShardPlan};
use crate::problems::{BlockOracle, OraclePayload};
use anyhow::{anyhow, bail, ensure, Result};
use std::io::{Read, Write};

/// Frame magic: `b"apfw"` little-endian. A connection speaking anything
/// else is rejected at the first frame.
pub const MAGIC: u32 = u32::from_le_bytes(*b"apfw");

/// Protocol version. Breaking changes bump this; a receiver rejects any
/// frame whose version it does not implement. v2 added the elastic-fleet
/// messages ([`Msg::Join`], [`Msg::Heartbeat`]); v3 added the sharded
/// parameter plane ([`Hello::shard`] + [`Hello::plan`] in the
/// handshake). Older peers are rejected at the first frame (see
/// `docs/WIRE.md` §8 for the compatibility rules).
pub const VERSION: u16 = 3;

/// Fixed frame header size in bytes: magic (4) + version (2) + type (1) +
/// reserved (1) + payload length (4).
pub const HEADER_BYTES: usize = 12;

/// Upper bound on a frame's payload length (guards against reading a
/// corrupt or hostile length prefix as an allocation size).
pub const MAX_FRAME_BYTES: u32 = 1 << 28;

/// Message type tags (the `docs/WIRE.md` message table).
mod tag {
    pub const HELLO: u8 = 1;
    pub const SNAPSHOT_REQUEST: u8 = 2;
    pub const SNAPSHOT: u8 = 3;
    pub const UPDATE: u8 = 4;
    pub const SHUTDOWN: u8 = 5;
    pub const HEARTBEAT: u8 = 6;
    pub const JOIN: u8 = 7;
}

/// Is `buf` (a complete encoded frame) an `Update` frame? Used by the
/// chaos layer to fault-inject at oracle-payload granularity without
/// corrupting the framing of control messages.
pub(crate) fn frame_is_update(buf: &[u8]) -> bool {
    buf.len() >= HEADER_BYTES
        && u32::from_le_bytes(buf[0..4].try_into().unwrap()) == MAGIC
        && buf[6] == tag::UPDATE
}

/// Handshake sent by the server immediately after accepting a worker
/// connection: everything the worker needs to rebuild the problem
/// instance deterministically and run its oracle loop.
#[derive(Debug, Clone, PartialEq)]
pub struct Hello {
    /// Worker id assigned by the server (also the rng stream selector).
    pub worker_id: u32,
    /// Run seed (data generation and block sampling).
    pub seed: u64,
    /// Server minibatch size tau (informational; the server assembles).
    pub tau: u32,
    /// Worker fan-out batch tau_w: blocks solved per snapshot.
    pub batch: u32,
    /// The `run.payload` knob: 0 = auto, 1 = dense, 2 = sparse.
    pub payload_mode: u8,
    /// Expected block count n — the worker cross-checks its rebuilt
    /// instance against this to catch configuration drift.
    pub n_blocks: u32,
    /// Registered problem name (`gfl`, `ssvm`, `multiclass`, `qp`).
    pub problem: String,
    /// Flattened config entries (`section.key`, `value`) the worker feeds
    /// back into `ProblemInstance::from_config`.
    pub config: Vec<(String, String)>,
    /// Which shard of `plan` issued this Hello (v3). 0 for the
    /// unsharded server.
    pub shard: u32,
    /// The session's block→shard routing table (v3). The degenerate
    /// one-shard plan for `run.shards = 1`; workers validate it against
    /// the rebuilt problem before trusting it.
    pub plan: ShardPlan,
}

/// A parameter snapshot body: the full vector, or only the ranges dirtied
/// since the version the worker already holds.
#[derive(Debug, Clone, PartialEq)]
pub enum SnapshotBody {
    /// The whole parameter vector.
    Full(Vec<f32>),
    /// Dirty `(offset, values)` runs to splice into the worker's copy. An
    /// empty delta is valid: the worker's copy is already current.
    Delta(Vec<(u32, Vec<f32>)>),
}

/// One wire message. `Update` reuses the in-memory [`BlockOracle`] shape
/// directly so the encode/decode path is the only representation change
/// between a worker's slots and the server's assembler.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Server -> worker handshake.
    Hello(Hello),
    /// Worker -> server: send me the parameter; I hold `have_version`
    /// (`u64::MAX` = nothing yet, always answered with a full snapshot).
    SnapshotRequest {
        /// Version the worker already holds.
        have_version: u64,
    },
    /// Server -> worker parameter snapshot at `version`.
    Snapshot {
        /// Server iteration the body reflects.
        version: u64,
        /// Full vector or dirty-range delta.
        body: SnapshotBody,
    },
    /// Worker -> server multi-block oracle payload, all solved against the
    /// snapshot of iteration `k_read`.
    Update {
        /// Snapshot version the oracles were computed from.
        k_read: u64,
        /// Sender worker id.
        worker: u32,
        /// Oracles for pairwise-distinct blocks (dense or sparse payloads,
        /// shipped in their in-memory representation).
        oracles: Vec<BlockOracle>,
    },
    /// Server -> worker: the solve is over; close the connection.
    Shutdown,
    /// Worker -> server keepalive (v2). Carries no payload; receiving any
    /// frame refreshes the connection's last-seen time, and a worker in a
    /// long oracle computation sends these so a liveness timeout does not
    /// mistake slow for dead. Never forwarded into the server's event
    /// ordering.
    Heartbeat,
    /// Worker -> server (v2): the first frame after the handshake.
    /// `resumed` distinguishes a reconnect-with-backoff session (the
    /// worker lost a prior connection mid-run) from a fresh join — the
    /// server's `reconnects` telemetry counts the former.
    Join {
        /// True when this session replaces one that broke mid-run.
        resumed: bool,
    },
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello(_) => tag::HELLO,
            Msg::SnapshotRequest { .. } => tag::SNAPSHOT_REQUEST,
            Msg::Snapshot { .. } => tag::SNAPSHOT,
            Msg::Update { .. } => tag::UPDATE,
            Msg::Shutdown => tag::SHUTDOWN,
            Msg::Heartbeat => tag::HEARTBEAT,
            Msg::Join { .. } => tag::JOIN,
        }
    }
}

// --- primitive writers -------------------------------------------------

fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, vs: &[f32]) {
    put_u32(buf, vs.len() as u32);
    for v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_u32s(buf: &mut Vec<u8>, vs: &[u32]) {
    put_u32(buf, vs.len() as u32);
    for v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

// --- primitive readers (bounds-checked cursor) -------------------------

/// Bounds-checked decode cursor over one frame payload. Every read is
/// explicit about truncation so a short frame fails with a clean error
/// instead of a panic.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.buf.len(),
            "truncated frame payload: wanted {} bytes at offset {}, have {}",
            n,
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A `u32` used as an element count: additionally bounded by the
    /// remaining payload so a corrupt count cannot drive a huge
    /// allocation before the truncation check fires.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        ensure!(
            n.saturating_mul(elem_bytes) <= self.buf.len() - self.pos,
            "frame count {} x {} bytes exceeds the remaining payload ({})",
            n,
            elem_bytes,
            self.buf.len() - self.pos
        );
        Ok(n)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.count(4)?;
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        let raw = self.take(n)?;
        Ok(std::str::from_utf8(raw)
            .map_err(|_| anyhow!("frame string is not valid UTF-8"))?
            .to_string())
    }
}

// --- payload encoding ---------------------------------------------------

/// Payload representation tags on the wire.
const PAYLOAD_DENSE: u8 = 0;
const PAYLOAD_SPARSE: u8 = 1;

/// Encode an [`OraclePayload`] body. Dense: `0 | dim | f32[dim]`. Sparse:
/// `1 | dim | nnz | u32 idx[nnz] | f32 val[nnz]` — the sparse triple ships
/// as-is, never densified.
fn put_payload(buf: &mut Vec<u8>, s: &OraclePayload) {
    match s {
        OraclePayload::Dense(v) => {
            put_u8(buf, PAYLOAD_DENSE);
            put_f32s(buf, v);
        }
        OraclePayload::Sparse { idx, val, dim } => {
            put_u8(buf, PAYLOAD_SPARSE);
            put_u32(buf, *dim);
            put_u32s(buf, idx);
            put_f32s(buf, val);
        }
    }
}

/// Decode an [`OraclePayload`], preserving the wire representation and
/// validating the sparse invariants (parallel arrays; strictly ascending,
/// in-bounds indices) so a corrupt frame cannot poison the apply path.
fn get_payload(d: &mut Dec) -> Result<OraclePayload> {
    match d.u8()? {
        PAYLOAD_DENSE => Ok(OraclePayload::Dense(d.f32s()?)),
        PAYLOAD_SPARSE => {
            let dim = d.u32()?;
            let idx = d.u32s()?;
            let val = d.f32s()?;
            ensure!(
                idx.len() == val.len(),
                "sparse payload idx/val length mismatch ({} vs {})",
                idx.len(),
                val.len()
            );
            ensure!(
                idx.windows(2).all(|w| w[0] < w[1]),
                "sparse payload indices are not strictly ascending"
            );
            ensure!(
                idx.last().map_or(true, |&i| i < dim),
                "sparse payload index out of bounds (dim {dim})"
            );
            Ok(OraclePayload::Sparse { idx, val, dim })
        }
        other => bail!("unknown payload representation tag {other}"),
    }
}

// --- message encoding ---------------------------------------------------

fn put_body(buf: &mut Vec<u8>, msg: &Msg) {
    match msg {
        Msg::Hello(h) => {
            put_u32(buf, h.worker_id);
            put_u64(buf, h.seed);
            put_u32(buf, h.tau);
            put_u32(buf, h.batch);
            put_u8(buf, h.payload_mode);
            put_u32(buf, h.n_blocks);
            put_str(buf, &h.problem);
            put_u32(buf, h.config.len() as u32);
            for (k, v) in &h.config {
                put_str(buf, k);
                put_str(buf, v);
            }
            // v3: issuing shard + the block->shard routing table.
            put_u32(buf, h.shard);
            put_u32(buf, h.plan.shards.len() as u32);
            for sh in &h.plan.shards {
                put_str(buf, &sh.addr);
                put_u32(buf, sh.block_start);
                put_u32(buf, sh.block_end);
                put_u32(buf, sh.param_start);
                put_u32(buf, sh.param_end);
            }
        }
        Msg::SnapshotRequest { have_version } => {
            put_u64(buf, *have_version);
        }
        Msg::Snapshot { version, body } => {
            put_u64(buf, *version);
            match body {
                SnapshotBody::Full(v) => {
                    put_u8(buf, 0);
                    put_f32s(buf, v);
                }
                SnapshotBody::Delta(runs) => {
                    put_u8(buf, 1);
                    put_u32(buf, runs.len() as u32);
                    for (off, vals) in runs {
                        put_u32(buf, *off);
                        put_f32s(buf, vals);
                    }
                }
            }
        }
        Msg::Update {
            k_read,
            worker,
            oracles,
        } => {
            put_u64(buf, *k_read);
            put_u32(buf, *worker);
            put_u32(buf, oracles.len() as u32);
            for o in oracles {
                put_u32(buf, o.block as u32);
                put_f64(buf, o.ls);
                put_payload(buf, &o.s);
            }
        }
        Msg::Shutdown => {}
        Msg::Heartbeat => {}
        Msg::Join { resumed } => {
            put_u8(buf, u8::from(*resumed));
        }
    }
}

fn get_body(tag_byte: u8, payload: &[u8]) -> Result<Msg> {
    let mut d = Dec::new(payload);
    let msg = match tag_byte {
        tag::HELLO => {
            let worker_id = d.u32()?;
            let seed = d.u64()?;
            let tau = d.u32()?;
            let batch = d.u32()?;
            let payload_mode = d.u8()?;
            let n_blocks = d.u32()?;
            let problem = d.str()?;
            let npairs = d.count(8)?;
            let mut config = Vec::with_capacity(npairs);
            for _ in 0..npairs {
                let k = d.str()?;
                let v = d.str()?;
                config.push((k, v));
            }
            let shard = d.u32()?;
            // Each plan entry is at least 20 bytes (addr length prefix
            // + four u32 spans), bounding a hostile count.
            let nshards = d.count(20)?;
            let mut shards = Vec::with_capacity(nshards);
            for _ in 0..nshards {
                let addr = d.str()?;
                shards.push(ShardInfo {
                    addr,
                    block_start: d.u32()?,
                    block_end: d.u32()?,
                    param_start: d.u32()?,
                    param_end: d.u32()?,
                });
            }
            ensure!(
                (shard as usize) < shards.len(),
                "Hello names shard {shard} of a {}-shard plan",
                shards.len()
            );
            Msg::Hello(Hello {
                worker_id,
                seed,
                tau,
                batch,
                payload_mode,
                n_blocks,
                problem,
                config,
                shard,
                plan: ShardPlan { shards },
            })
        }
        tag::SNAPSHOT_REQUEST => Msg::SnapshotRequest {
            have_version: d.u64()?,
        },
        tag::SNAPSHOT => {
            let version = d.u64()?;
            let body = match d.u8()? {
                0 => SnapshotBody::Full(d.f32s()?),
                1 => {
                    let nruns = d.count(8)?;
                    let mut runs = Vec::with_capacity(nruns);
                    for _ in 0..nruns {
                        let off = d.u32()?;
                        runs.push((off, d.f32s()?));
                    }
                    SnapshotBody::Delta(runs)
                }
                other => bail!("unknown snapshot body tag {other}"),
            };
            Msg::Snapshot { version, body }
        }
        tag::UPDATE => {
            let k_read = d.u64()?;
            let worker = d.u32()?;
            let count = d.count(13)?;
            let mut oracles = Vec::with_capacity(count);
            for _ in 0..count {
                let block = d.u32()? as usize;
                let ls = d.f64()?;
                let s = get_payload(&mut d)?;
                oracles.push(BlockOracle { block, s, ls });
            }
            Msg::Update {
                k_read,
                worker,
                oracles,
            }
        }
        tag::SHUTDOWN => Msg::Shutdown,
        tag::HEARTBEAT => Msg::Heartbeat,
        tag::JOIN => Msg::Join {
            resumed: d.u8()? != 0,
        },
        other => bail!("unknown message type {other} (protocol v{VERSION})"),
    };
    // Forward compatibility: trailing bytes beyond what this version
    // consumes are permitted (additive extension); a SHORT payload is
    // rejected by the cursor above.
    Ok(msg)
}

// --- framing ------------------------------------------------------------

/// Encode `msg` as one complete frame (header + payload) into `buf`
/// (cleared first; capacity reused across calls). Returns the frame size
/// in bytes — the unit of the `wire_*_bytes` telemetry counters.
pub fn encode_frame(msg: &Msg, buf: &mut Vec<u8>) -> usize {
    buf.clear();
    put_u32(buf, MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    put_u8(buf, msg.tag());
    put_u8(buf, 0); // reserved
    put_u32(buf, 0); // payload length backpatched below
    put_body(buf, msg);
    let len = (buf.len() - HEADER_BYTES) as u32;
    buf[8..12].copy_from_slice(&len.to_le_bytes());
    buf.len()
}

/// Write `msg` as one frame. Returns the bytes put on the wire. `buf` is
/// the caller's encode scratch (reused across calls). Errors — without
/// emitting anything — on a payload above [`MAX_FRAME_BYTES`]: every
/// compliant decoder would reject such a frame, and sending it anyway
/// would surface as a confusing peer-side disconnect instead of this
/// sender-side error.
pub fn write_frame(
    w: &mut impl Write,
    msg: &Msg,
    buf: &mut Vec<u8>,
) -> Result<usize> {
    let n = encode_frame(msg, buf);
    ensure!(
        n - HEADER_BYTES <= MAX_FRAME_BYTES as usize,
        "refusing to send a {}-byte frame payload (cap: {MAX_FRAME_BYTES}; \
         is the parameter dimension beyond the wire protocol's design \
         range?)",
        n - HEADER_BYTES
    );
    w.write_all(buf)?;
    Ok(n)
}

/// Read one frame. Returns `Ok(None)` on a clean end-of-stream (the peer
/// closed before any header byte); errors on bad magic, an unsupported
/// version, an unknown message type, an oversized length prefix, or a
/// frame truncated mid-way.
pub fn read_frame(r: &mut impl Read) -> Result<Option<(Msg, usize)>> {
    let mut header = [0u8; HEADER_BYTES];
    // Distinguish clean EOF (no bytes at a frame boundary) from a header
    // truncated part-way through.
    let mut got = 0usize;
    while got < HEADER_BYTES {
        let n = r.read(&mut header[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("truncated frame header ({got} of {HEADER_BYTES} bytes)");
        }
        got += n;
    }
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    ensure!(
        magic == MAGIC,
        "bad frame magic {magic:#010x} (expected {MAGIC:#010x}) — not an \
         apbcfw peer?"
    );
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    ensure!(
        version == VERSION,
        "unsupported protocol version {version} (this build speaks v{VERSION})"
    );
    let tag_byte = header[6];
    let len = u32::from_le_bytes(header[8..12].try_into().unwrap());
    ensure!(
        len <= MAX_FRAME_BYTES,
        "frame payload length {len} exceeds the {MAX_FRAME_BYTES}-byte cap"
    );
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| anyhow!("truncated frame payload: {e}"))?;
    let msg = get_body(tag_byte, &payload)?;
    Ok(Some((msg, HEADER_BYTES + len as usize)))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Encode-then-decode helper over an in-memory cursor.
    fn roundtrip(msg: &Msg) -> Msg {
        let mut buf = Vec::new();
        let n = encode_frame(msg, &mut buf);
        assert_eq!(n, buf.len());
        let mut cursor: &[u8] = &buf;
        let (decoded, consumed) =
            read_frame(&mut cursor).unwrap().expect("not EOF");
        assert_eq!(consumed, n);
        assert!(cursor.is_empty(), "frame must consume itself exactly");
        decoded
    }

    #[test]
    fn roundtrips_every_message_type() {
        let msgs = [
            Msg::Hello(Hello {
                worker_id: 3,
                seed: 99,
                tau: 4,
                batch: 2,
                payload_mode: 2,
                n_blocks: 39,
                problem: "gfl".into(),
                config: vec![
                    ("gfl.d".into(), "6".into()),
                    ("run.seed".into(), "5".into()),
                ],
                shard: 1,
                plan: ShardPlan {
                    shards: vec![
                        ShardInfo {
                            addr: "127.0.0.1:7920".into(),
                            block_start: 0,
                            block_end: 20,
                            param_start: 0,
                            param_end: 120,
                        },
                        ShardInfo {
                            addr: "127.0.0.1:7921".into(),
                            block_start: 20,
                            block_end: 39,
                            param_start: 120,
                            param_end: 234,
                        },
                    ],
                },
            }),
            Msg::SnapshotRequest {
                have_version: u64::MAX,
            },
            Msg::Snapshot {
                version: 17,
                body: SnapshotBody::Full(vec![1.0, -2.5, f32::MIN_POSITIVE]),
            },
            Msg::Snapshot {
                version: 18,
                body: SnapshotBody::Delta(vec![
                    (0, vec![0.5]),
                    (7, vec![1.0, 2.0]),
                ]),
            },
            Msg::Snapshot {
                version: 18,
                body: SnapshotBody::Delta(vec![]),
            },
            Msg::Update {
                k_read: 12,
                worker: 1,
                oracles: vec![
                    BlockOracle::dense(4, vec![0.0, 1.0], 0.25),
                    BlockOracle {
                        block: 9,
                        s: OraclePayload::Sparse {
                            idx: vec![0, 5],
                            val: vec![-1.0, 3.5],
                            dim: 8,
                        },
                        ls: -0.5,
                    },
                    BlockOracle {
                        block: 2,
                        s: OraclePayload::Sparse {
                            idx: vec![],
                            val: vec![],
                            dim: 8,
                        },
                        ls: 0.0,
                    },
                ],
            },
            Msg::Shutdown,
            Msg::Heartbeat,
            Msg::Join { resumed: false },
            Msg::Join { resumed: true },
        ];
        for msg in &msgs {
            assert_eq!(&roundtrip(msg), msg);
        }
    }

    #[test]
    fn v1_peer_frames_are_rejected_with_a_version_error() {
        // A v1 build writes version=1 in the header; this v3 build must
        // reject it cleanly (docs/WIRE.md §8: both roles ship in one
        // binary, so a version skew means mismatched deployments).
        let mut buf = Vec::new();
        encode_frame(&Msg::Shutdown, &mut buf);
        buf[4..6].copy_from_slice(&1u16.to_le_bytes());
        let err = read_frame(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("version 1"), "{err}");
        assert!(err.contains("v3"), "{err}");
    }

    #[test]
    fn hello_rejects_an_out_of_plan_shard_index() {
        let hello = Msg::Hello(Hello {
            worker_id: 0,
            seed: 1,
            tau: 1,
            batch: 1,
            payload_mode: 0,
            n_blocks: 4,
            problem: "gfl".into(),
            config: vec![],
            shard: 0,
            plan: ShardPlan::single("h:1".into(), 4, 16),
        });
        let mut buf = Vec::new();
        encode_frame(&hello, &mut buf);
        // Corrupt the shard index (the u32 right after the config
        // pairs) to point past the one-shard plan.
        let shard_off = buf.len() - (4 + 4 + (4 + 3) + 16);
        buf[shard_off..shard_off + 4]
            .copy_from_slice(&9u32.to_le_bytes());
        let err = read_frame(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("shard 9"), "{err}");
    }

    #[test]
    fn update_frames_are_recognized_for_chaos_injection() {
        let mut buf = Vec::new();
        encode_frame(
            &Msg::Update {
                k_read: 0,
                worker: 0,
                oracles: vec![],
            },
            &mut buf,
        );
        assert!(frame_is_update(&buf));
        for other in [
            Msg::Shutdown,
            Msg::Heartbeat,
            Msg::Join { resumed: true },
            Msg::SnapshotRequest { have_version: 0 },
        ] {
            encode_frame(&other, &mut buf);
            assert!(!frame_is_update(&buf), "{other:?}");
        }
        assert!(!frame_is_update(&[0u8; 4]));
    }

    #[test]
    fn sparse_payload_survives_the_wire_sparse() {
        let msg = Msg::Update {
            k_read: 0,
            worker: 0,
            oracles: vec![BlockOracle {
                block: 0,
                s: OraclePayload::Sparse {
                    idx: vec![2],
                    val: vec![1.0],
                    dim: 100,
                },
                ls: 0.0,
            }],
        };
        match roundtrip(&msg) {
            Msg::Update { oracles, .. } => match &oracles[0].s {
                OraclePayload::Sparse { idx, val, dim } => {
                    assert_eq!((idx.as_slice(), val.as_slice(), *dim),
                        ([2u32].as_slice(), [1.0f32].as_slice(), 100));
                }
                other => panic!("densified on the wire: {other:?}"),
            },
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn truncation_anywhere_is_rejected_not_a_panic() {
        let msg = Msg::Update {
            k_read: 5,
            worker: 0,
            oracles: vec![BlockOracle {
                block: 1,
                s: OraclePayload::Sparse {
                    idx: vec![1, 3],
                    val: vec![0.5, -0.5],
                    dim: 6,
                },
                ls: 1.5,
            }],
        };
        let mut buf = Vec::new();
        let n = encode_frame(&msg, &mut buf);
        for cut in 1..n {
            let mut cursor: &[u8] = &buf[..cut];
            assert!(
                read_frame(&mut cursor).is_err(),
                "cut at {cut} of {n} must error"
            );
        }
        // Zero bytes is the one clean case: EOF at a frame boundary.
        let mut empty: &[u8] = &[];
        assert!(read_frame(&mut empty).unwrap().is_none());
    }

    #[test]
    fn bad_magic_version_and_type_are_rejected() {
        let mut buf = Vec::new();
        encode_frame(&Msg::Shutdown, &mut buf);

        let mut bad = buf.clone();
        bad[0] ^= 0xff;
        let err = read_frame(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");

        let mut bad = buf.clone();
        bad[4] = 0xfe; // version
        let err = read_frame(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");

        let mut bad = buf.clone();
        bad[6] = 0xee; // message type
        let err = read_frame(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(err.contains("message type"), "{err}");

        let mut bad = buf;
        bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(err.contains("cap"), "{err}");
    }

    #[test]
    fn corrupt_sparse_invariants_are_rejected() {
        // Descending indices.
        let msg = Msg::Update {
            k_read: 0,
            worker: 0,
            oracles: vec![BlockOracle {
                block: 0,
                s: OraclePayload::Sparse {
                    idx: vec![5, 2],
                    val: vec![1.0, 2.0],
                    dim: 8,
                },
                ls: 0.0,
            }],
        };
        let mut buf = Vec::new();
        encode_frame(&msg, &mut buf);
        let err = read_frame(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("ascending"), "{err}");

        // Out-of-bounds index.
        let msg = Msg::Update {
            k_read: 0,
            worker: 0,
            oracles: vec![BlockOracle {
                block: 0,
                s: OraclePayload::Sparse {
                    idx: vec![8],
                    val: vec![1.0],
                    dim: 8,
                },
                ls: 0.0,
            }],
        };
        encode_frame(&msg, &mut buf);
        let err = read_frame(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("bounds"), "{err}");
    }

    #[test]
    fn trailing_bytes_are_tolerated_for_forward_compat() {
        // A v1 decoder must accept a payload longer than it consumes
        // (additive extension by a newer minor revision).
        let mut buf = Vec::new();
        encode_frame(
            &Msg::SnapshotRequest { have_version: 7 },
            &mut buf,
        );
        buf.extend_from_slice(&[0xab, 0xcd]); // extension bytes
        let len = (buf.len() - HEADER_BYTES) as u32;
        buf[8..12].copy_from_slice(&len.to_le_bytes());
        let (msg, n) = read_frame(&mut buf.as_slice()).unwrap().unwrap();
        assert_eq!(msg, Msg::SnapshotRequest { have_version: 7 });
        assert_eq!(n, buf.len());
    }

    #[test]
    fn frame_sizes_reflect_payload_sparsity() {
        // The whole point of the sparse pipeline: a 1-hot vertex over a
        // large dim ships O(1) bytes where dense ships O(dim).
        let sparse = Msg::Update {
            k_read: 0,
            worker: 0,
            oracles: vec![BlockOracle {
                block: 0,
                s: OraclePayload::Sparse {
                    idx: vec![500],
                    val: vec![1.0],
                    dim: 1000,
                },
                ls: 0.0,
            }],
        };
        let mut dense_s = vec![0.0f32; 1000];
        dense_s[500] = 1.0;
        let dense = Msg::Update {
            k_read: 0,
            worker: 0,
            oracles: vec![BlockOracle::dense(0, dense_s, 0.0)],
        };
        let mut buf = Vec::new();
        let ns = encode_frame(&sparse, &mut buf);
        let nd = encode_frame(&dense, &mut buf);
        assert!(ns < 100, "sparse frame is {ns} bytes");
        assert!(nd > 4000, "dense frame is {nd} bytes");
    }
}
