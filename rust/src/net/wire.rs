//! The versioned, length-prefixed binary wire codec.
//!
//! Hand-rolled little-endian encoding over any `Read`/`Write` pair (a
//! `TcpStream` in production, a `Vec<u8>` cursor in the round-trip tests).
//! The normative protocol specification — frame layout, message table,
//! version and endianness rules, payload encodings, forward-compatibility
//! notes — lives in `docs/WIRE.md`; this module is its reference
//! implementation and must stay in sync with it.
//!
//! The design constraint that shapes everything here: a sparse
//! [`OraclePayload`] is encoded as its `(idx, val, dim)` triple and decoded
//! back into the same variant, so payload sparsity survives the wire
//! end-to-end — the decoder never densifies (pinned by the codec tests in
//! `rust/tests/net_transport.rs`).
//!
//! v4 adds the communication-efficient encodings behind the `run.wire`
//! knob ([`WireMode`]): sparse payload *values* may ship quantized (f16
//! half precision or int8 with a per-payload max-abs scale) and snapshot
//! bodies may ship with compressed-but-lossless layouts (varint delta
//! headers, zero-run-length full bodies). The mode is an encoder-side
//! choice only — every decoder accepts every encoding, and
//! [`WireMode::Exact`] (the default) emits bodies byte-identical to v3.
//!
//! v5 adds crash recovery's generation fencing: [`Hello`] carries the
//! session generation (bumped on every restore from a durable
//! checkpoint) plus the sampler fast-forward count, and every `Update`
//! frame is stamped with the generation its sender adopted — the server
//! fences frames from a stale generation so pre-crash in-flight oracles
//! can never corrupt a restored parameter (`docs/WIRE.md` §8).

use super::shard::{ShardInfo, ShardPlan};
use crate::problems::{BlockOracle, OraclePayload};
use anyhow::{anyhow, bail, ensure, Result};
use std::io::{Read, Write};

/// Frame magic: `b"apfw"` little-endian. A connection speaking anything
/// else is rejected at the first frame.
pub const MAGIC: u32 = u32::from_le_bytes(*b"apfw");

/// Protocol version. Breaking changes bump this; a receiver rejects any
/// frame whose version it does not implement. v2 added the elastic-fleet
/// messages ([`Msg::Join`], [`Msg::Heartbeat`]); v3 added the sharded
/// parameter plane ([`Hello::shard`] + [`Hello::plan`] in the
/// handshake); v4 added the communication-efficient encodings (quantized
/// sparse payload values, compressed snapshot bodies — the `run.wire`
/// knob); v5 added crash recovery's generation fencing
/// ([`Hello::generation`] + [`Hello::resume_draws`] in the handshake, a
/// generation stamp on every `Update` frame). Older peers are rejected at
/// the first frame (see `docs/WIRE.md` §8 for the compatibility rules).
pub const VERSION: u16 = 5;

/// Fixed frame header size in bytes: magic (4) + version (2) + type (1) +
/// reserved (1) + payload length (4).
pub const HEADER_BYTES: usize = 12;

/// Upper bound on a frame's payload length (guards against reading a
/// corrupt or hostile length prefix as an allocation size).
pub const MAX_FRAME_BYTES: u32 = 1 << 28;

/// The `run.wire` knob (v4): how update-payload values and snapshot
/// bodies are encoded on the wire.
///
/// `Exact` (the pinned default) ships every f32 bit-for-bit, with frame
/// bodies byte-identical to protocol v3. `F16` and `Q8` quantize
/// [`OraclePayload::Sparse`] *values* (half precision / int8 with a
/// per-payload max-abs scale) and switch snapshot bodies to the
/// compressed-but-lossless layouts (varint delta headers, zero-RLE full
/// bodies) — snapshots are what workers compute oracles on, so only the
/// update values are lossy. Dense payloads and control frames are
/// identical in every mode. The mode is an encoder-side choice: every v4
/// decoder accepts every encoding, so serve and worker only need to
/// *agree* for telemetry to be comparable (the knob ships to workers in
/// the Hello config entries).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireMode {
    /// Exact f32 values; bodies byte-identical to protocol v3.
    #[default]
    Exact,
    /// Sparse payload values as IEEE 754 half precision (2 bytes each).
    F16,
    /// Sparse payload values as int8 under a per-payload max-abs scale.
    Q8,
}

impl WireMode {
    /// Parse the `run.wire` knob text.
    pub fn parse(text: &str) -> Result<Self> {
        match text {
            "exact" => Ok(WireMode::Exact),
            "f16" => Ok(WireMode::F16),
            "q8" => Ok(WireMode::Q8),
            other => {
                bail!("run.wire: expected exact | f16 | q8, got {other:?}")
            }
        }
    }

    /// The knob text (the inverse of [`WireMode::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            WireMode::Exact => "exact",
            WireMode::F16 => "f16",
            WireMode::Q8 => "q8",
        }
    }
}

/// Message type tags (the `docs/WIRE.md` message table).
mod tag {
    pub const HELLO: u8 = 1;
    pub const SNAPSHOT_REQUEST: u8 = 2;
    pub const SNAPSHOT: u8 = 3;
    pub const UPDATE: u8 = 4;
    pub const SHUTDOWN: u8 = 5;
    pub const HEARTBEAT: u8 = 6;
    pub const JOIN: u8 = 7;
}

/// Is `buf` (a complete encoded frame) an `Update` frame? Used by the
/// chaos layer to fault-inject at oracle-payload granularity without
/// corrupting the framing of control messages.
pub(crate) fn frame_is_update(buf: &[u8]) -> bool {
    buf.len() >= HEADER_BYTES
        && u32::from_le_bytes(buf[0..4].try_into().unwrap()) == MAGIC
        && buf[6] == tag::UPDATE
}

/// Handshake sent by the server immediately after accepting a worker
/// connection: everything the worker needs to rebuild the problem
/// instance deterministically and run its oracle loop.
#[derive(Debug, Clone, PartialEq)]
pub struct Hello {
    /// Worker id assigned by the server (also the rng stream selector).
    pub worker_id: u32,
    /// Run seed (data generation and block sampling).
    pub seed: u64,
    /// Server minibatch size tau (informational; the server assembles).
    pub tau: u32,
    /// Worker fan-out batch tau_w: blocks solved per snapshot.
    pub batch: u32,
    /// The `run.payload` knob: 0 = auto, 1 = dense, 2 = sparse.
    pub payload_mode: u8,
    /// Expected block count n — the worker cross-checks its rebuilt
    /// instance against this to catch configuration drift.
    pub n_blocks: u32,
    /// Registered problem name (`gfl`, `ssvm`, `multiclass`, `qp`).
    pub problem: String,
    /// Flattened config entries (`section.key`, `value`) the worker feeds
    /// back into `ProblemInstance::from_config`.
    pub config: Vec<(String, String)>,
    /// Which shard of `plan` issued this Hello (v3). 0 for the
    /// unsharded server.
    pub shard: u32,
    /// The session's block→shard routing table (v3). The degenerate
    /// one-shard plan for `run.shards = 1`; workers validate it against
    /// the rebuilt problem before trusting it.
    pub plan: ShardPlan,
    /// Session generation (v5). 0 for a fresh run; each restore from a
    /// durable checkpoint bumps it. Workers stamp every `Update` frame
    /// they send with the generation they adopted here, and the server
    /// fences frames from any other generation (`stale_fenced`).
    pub generation: u64,
    /// Sampler fast-forward count (v5): how many `pick_blocks` draws this
    /// worker's rng stream must discard before its first round, so a
    /// worker resuming after a server restore replays the block sequence
    /// the restored iterate already reflects. 0 for a fresh run.
    pub resume_draws: u64,
}

/// A parameter snapshot body: the full vector, or only the ranges dirtied
/// since the version the worker already holds.
#[derive(Debug, Clone, PartialEq)]
pub enum SnapshotBody {
    /// The whole parameter vector.
    Full(Vec<f32>),
    /// Dirty `(offset, values)` runs to splice into the worker's copy. An
    /// empty delta is valid: the worker's copy is already current.
    Delta(Vec<(u32, Vec<f32>)>),
}

/// One wire message. `Update` reuses the in-memory [`BlockOracle`] shape
/// directly so the encode/decode path is the only representation change
/// between a worker's slots and the server's assembler.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Server -> worker handshake.
    Hello(Hello),
    /// Worker -> server: send me the parameter; I hold `have_version`
    /// (`u64::MAX` = nothing yet, always answered with a full snapshot).
    SnapshotRequest {
        /// Version the worker already holds.
        have_version: u64,
    },
    /// Server -> worker parameter snapshot at `version`.
    Snapshot {
        /// Server iteration the body reflects.
        version: u64,
        /// Full vector or dirty-range delta.
        body: SnapshotBody,
    },
    /// Worker -> server multi-block oracle payload, all solved against the
    /// snapshot of iteration `k_read`.
    Update {
        /// Snapshot version the oracles were computed from.
        k_read: u64,
        /// Sender worker id.
        worker: u32,
        /// Session generation the sender adopted from its Hello (v5).
        /// The server drops frames whose generation is not its own —
        /// the crash-recovery fence against pre-crash in-flight oracles.
        generation: u64,
        /// Oracles for pairwise-distinct blocks (dense or sparse payloads,
        /// shipped in their in-memory representation).
        oracles: Vec<BlockOracle>,
    },
    /// Server -> worker: the solve is over; close the connection.
    Shutdown,
    /// Worker -> server keepalive (v2). Carries no payload; receiving any
    /// frame refreshes the connection's last-seen time, and a worker in a
    /// long oracle computation sends these so a liveness timeout does not
    /// mistake slow for dead. Never forwarded into the server's event
    /// ordering.
    Heartbeat,
    /// Worker -> server (v2): the first frame after the handshake.
    /// `resumed` distinguishes a reconnect-with-backoff session (the
    /// worker lost a prior connection mid-run) from a fresh join — the
    /// server's `reconnects` telemetry counts the former.
    Join {
        /// True when this session replaces one that broke mid-run.
        resumed: bool,
    },
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello(_) => tag::HELLO,
            Msg::SnapshotRequest { .. } => tag::SNAPSHOT_REQUEST,
            Msg::Snapshot { .. } => tag::SNAPSHOT,
            Msg::Update { .. } => tag::UPDATE,
            Msg::Shutdown => tag::SHUTDOWN,
            Msg::Heartbeat => tag::HEARTBEAT,
            Msg::Join { .. } => tag::JOIN,
        }
    }
}

// --- primitive writers -------------------------------------------------

fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, vs: &[f32]) {
    put_u32(buf, vs.len() as u32);
    for v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_u32s(buf: &mut Vec<u8>, vs: &[u32]) {
    put_u32(buf, vs.len() as u32);
    for v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// LEB128 varint (u32: 1–5 bytes). The compressed snapshot layouts (v4)
/// use these for counts, run starts, and run lengths, which are small in
/// practice — a dirty run rarely starts megabytes after the previous one.
fn put_varint(buf: &mut Vec<u8>, mut v: u32) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(b);
            return;
        }
        buf.push(b | 0x80);
    }
}

// --- f16 conversion (v4 quantized payloads) -----------------------------
//
// Hand-rolled IEEE 754 binary16 <-> binary32 bit conversion (the vendor
// set has no `half` crate). Round-to-nearest on narrowing, overflow to
// infinity, subnormals handled on both sides; every finite f16 converts
// back exactly.

/// Narrow an f32 to f16 bits (round-to-nearest, overflow to infinity).
fn f32_to_f16(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN: keep the class (force a non-zero NaN mantissa so a
        // payload NaN cannot narrow into an infinity).
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15; // re-bias 127 -> 15
    if e >= 0x1f {
        return sign | 0x7c00; // overflow -> signed infinity
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow -> signed zero
        }
        // Subnormal: shift the implicit-1 mantissa into place, rounding
        // on the last dropped bit. A carry out of the mantissa promotes
        // to the smallest normal, which is exactly what rounding wants.
        let m = mant | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = m >> shift;
        let round = (m >> (shift - 1)) & 1;
        return sign | (half + round) as u16;
    }
    // Normal: drop 13 mantissa bits, rounding on the highest dropped
    // one. A carry ripples into the exponent (up to infinity) correctly.
    let half = ((e as u32) << 10) | (mant >> 13);
    let round = (mant >> 12) & 1;
    sign | (half + round) as u16
}

/// Widen f16 bits back to f32 (exact for every finite f16).
fn f16_to_f32(bits: u16) -> f32 {
    let sign = ((bits & 0x8000) as u32) << 16;
    let exp = ((bits >> 10) & 0x1f) as u32;
    let mant = (bits & 0x03ff) as u32;
    let out = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13) // Inf / NaN
    } else if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // Subnormal: renormalize into f32's wider exponent range.
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e += 1;
            }
            sign | (((127 - 15 - e) as u32) << 23) | ((m & 0x03ff) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(out)
}

// --- primitive readers (bounds-checked cursor) -------------------------

/// Bounds-checked decode cursor over one frame payload. Every read is
/// explicit about truncation so a short frame fails with a clean error
/// instead of a panic. `pub(crate)` so the checkpoint codec
/// (`super::checkpoint`) can reuse the same hardened cursor for its
/// on-disk format instead of growing a second, subtly different one.
pub(crate) struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes left between the cursor and the end of the payload.
    /// Saturating so every bounds comparison in this impl is safe even
    /// if an internal bug ever ran the cursor past the end — the decoder
    /// must degrade to a clean `Err`, never to arithmetic overflow.
    pub(crate) fn remaining(&self) -> usize {
        self.buf.len().saturating_sub(self.pos)
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // Checked as `n <= remaining` rather than `pos + n <= len`: the
        // latter can overflow `usize` on a hostile `n` and panic in a
        // debug build before the bound is ever tested.
        ensure!(
            n <= self.remaining(),
            "truncated frame payload: wanted {} bytes at offset {}, have {}",
            n,
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// LEB128 varint (u32). Rejects encodings longer than 5 bytes and
    /// high bits that overflow 32, so a corrupt stream cannot loop or
    /// silently wrap.
    pub(crate) fn varint(&mut self) -> Result<u32> {
        let mut v: u32 = 0;
        for shift in [0u32, 7, 14, 21, 28] {
            let b = self.u8()?;
            let low = u32::from(b & 0x7f);
            ensure!(
                shift < 28 || low <= 0x0f,
                "varint overflows u32 at offset {}",
                self.pos
            );
            v |= low << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
        }
        bail!("varint longer than 5 bytes at offset {}", self.pos)
    }

    /// A `u32` used as an element count: additionally bounded by the
    /// remaining payload so a corrupt count cannot drive a huge
    /// allocation before the truncation check fires. All arithmetic is
    /// saturating — a hostile count must fail the bound, not overflow it.
    pub(crate) fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        ensure!(
            n.saturating_mul(elem_bytes) <= self.remaining(),
            "frame count {} x {} bytes exceeds the remaining payload ({})",
            n,
            elem_bytes,
            self.remaining()
        );
        Ok(n)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.count(4)?;
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub(crate) fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        let raw = self.take(n)?;
        Ok(std::str::from_utf8(raw)
            .map_err(|_| anyhow!("frame string is not valid UTF-8"))?
            .to_string())
    }
}

// --- payload encoding ---------------------------------------------------

/// Payload representation tags on the wire.
const PAYLOAD_DENSE: u8 = 0;
const PAYLOAD_SPARSE: u8 = 1;
/// v4: sparse values as IEEE 754 half precision.
const PAYLOAD_SPARSE_F16: u8 = 2;
/// v4: sparse values as int8 under a per-payload max-abs scale.
const PAYLOAD_SPARSE_Q8: u8 = 3;

/// Encode an [`OraclePayload`] body under `mode`. Dense:
/// `0 | dim | f32[dim]` — always exact, in every mode (the quantization
/// targets are the sparse LMO-vertex values; GFL's dense fallback stays
/// lossless). Sparse exact: `1 | dim | nnz | u32 idx[nnz] | f32 val[nnz]`.
/// Sparse f16 (v4): `2 | dim | nnz | u32 idx[nnz] | nval | u16 f16[nval]`.
/// Sparse q8 (v4): `3 | dim | nnz | u32 idx[nnz] | f32 scale | nval |
/// i8 q[nval]` with `val = q * scale / 127` and `scale` the payload's
/// max-abs value (an all-zero payload ships scale 0). The sparse triple
/// ships as-is in every mode, never densified.
fn put_payload(buf: &mut Vec<u8>, s: &OraclePayload, mode: WireMode) {
    match s {
        OraclePayload::Dense(v) => {
            put_u8(buf, PAYLOAD_DENSE);
            put_f32s(buf, v);
        }
        OraclePayload::Sparse { idx, val, dim } => match mode {
            WireMode::Exact => {
                put_u8(buf, PAYLOAD_SPARSE);
                put_u32(buf, *dim);
                put_u32s(buf, idx);
                put_f32s(buf, val);
            }
            WireMode::F16 => {
                put_u8(buf, PAYLOAD_SPARSE_F16);
                put_u32(buf, *dim);
                put_u32s(buf, idx);
                put_u32(buf, val.len() as u32);
                for v in val {
                    buf.extend_from_slice(&f32_to_f16(*v).to_le_bytes());
                }
            }
            WireMode::Q8 => {
                put_u8(buf, PAYLOAD_SPARSE_Q8);
                put_u32(buf, *dim);
                put_u32s(buf, idx);
                let scale =
                    val.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                buf.extend_from_slice(&scale.to_le_bytes());
                put_u32(buf, val.len() as u32);
                for v in val {
                    // Saturating float->int cast: NaN (including the
                    // scale-0 all-zero payload's 0/0) lands on 0, out of
                    // range clamps to the i8 bounds.
                    let q = (v / scale * 127.0).round() as i8;
                    buf.push(q as u8);
                }
            }
        },
    }
}

/// Validate the sparse invariants (parallel arrays; strictly ascending,
/// in-bounds indices) so a corrupt frame cannot poison the apply path.
fn sparse_checked(
    idx: Vec<u32>,
    val: Vec<f32>,
    dim: u32,
) -> Result<OraclePayload> {
    ensure!(
        idx.len() == val.len(),
        "sparse payload idx/val length mismatch ({} vs {})",
        idx.len(),
        val.len()
    );
    ensure!(
        idx.windows(2).all(|w| w[0] < w[1]),
        "sparse payload indices are not strictly ascending"
    );
    ensure!(
        idx.last().map_or(true, |&i| i < dim),
        "sparse payload index out of bounds (dim {dim})"
    );
    Ok(OraclePayload::Sparse { idx, val, dim })
}

/// Decode an [`OraclePayload`], preserving the wire representation and
/// dequantizing f16/q8 values back to f32 in place — downstream of this
/// function ([`crate::coordinator::apply::ApplyCore`] included) only ever
/// sees the two in-memory variants, whatever the sender's [`WireMode`].
/// Every representation tag is accepted regardless of the local mode.
fn get_payload(d: &mut Dec) -> Result<OraclePayload> {
    match d.u8()? {
        PAYLOAD_DENSE => Ok(OraclePayload::Dense(d.f32s()?)),
        PAYLOAD_SPARSE => {
            let dim = d.u32()?;
            let idx = d.u32s()?;
            let val = d.f32s()?;
            sparse_checked(idx, val, dim)
        }
        PAYLOAD_SPARSE_F16 => {
            let dim = d.u32()?;
            let idx = d.u32s()?;
            let n = d.count(2)?;
            let raw = d.take(2 * n)?;
            let val = raw
                .chunks_exact(2)
                .map(|c| {
                    f16_to_f32(u16::from_le_bytes(c.try_into().unwrap()))
                })
                .collect();
            sparse_checked(idx, val, dim)
        }
        PAYLOAD_SPARSE_Q8 => {
            let dim = d.u32()?;
            let idx = d.u32s()?;
            let scale = d.f32()?;
            let n = d.count(1)?;
            let raw = d.take(n)?;
            let val = raw
                .iter()
                .map(|&b| (b as i8) as f32 * scale / 127.0)
                .collect();
            sparse_checked(idx, val, dim)
        }
        other => bail!("unknown payload representation tag {other}"),
    }
}

// --- snapshot body encoding (v4 compressed layouts) ---------------------

/// Snapshot body kind tags (`docs/WIRE.md` §4.3).
const SNAP_FULL: u8 = 0;
const SNAP_DELTA: u8 = 1;
/// v4: delta with varint headers (delta-of-start + run length).
const SNAP_DELTA_V: u8 = 2;
/// v4: full body under zero-run-length compression.
const SNAP_FULL_RLE: u8 = 3;

/// Kind 2: the delta body with a compressed header — run count, then per
/// run the start as a (wrapping) delta from the previous run's start and
/// the run length, all varints; then every run's raw f32 values back to
/// back. The dirty-range log emits runs ascending with small gaps, so
/// the 8-byte-per-run exact header shrinks to ~2 bytes — while the
/// values themselves stay exact: snapshots are what workers compute
/// oracles on, so only the header is squeezed, never the parameter.
fn put_delta_varint(buf: &mut Vec<u8>, runs: &[(u32, Vec<f32>)]) {
    put_u8(buf, SNAP_DELTA_V);
    put_varint(buf, runs.len() as u32);
    let mut prev = 0u32;
    for (off, vals) in runs {
        put_varint(buf, off.wrapping_sub(prev));
        put_varint(buf, vals.len() as u32);
        prev = *off;
    }
    for (_, vals) in runs {
        for v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Kind 3: the full-snapshot fallback under zero-run-length compression
/// — the vector length, then alternating (zero run, literal run) varint
/// pairs, each literal run followed by its raw f32 values. Only the bit
/// pattern of +0.0 joins a zero run (−0.0 ships as a literal), so the
/// decode is bit-exact. FW iterates are convex combinations of a few
/// vertices early in a run, so resync full bodies are mostly zeros and
/// stop dominating `wire_tx_bytes`.
/// `pub(crate)`: the checkpoint codec (`super::checkpoint`) persists the
/// master parameter with exactly this lossless layout — the ISSUE's
/// "reuse the wire-v4 snapshot encoders" requirement, and the reason a
/// checkpointed param is bit-exact by construction.
pub(crate) fn put_full_rle(buf: &mut Vec<u8>, v: &[f32]) {
    put_u8(buf, SNAP_FULL_RLE);
    put_varint(buf, v.len() as u32);
    let mut i = 0usize;
    while i < v.len() {
        let z = v[i..].iter().take_while(|x| x.to_bits() == 0).count();
        i += z;
        let l = v[i..].iter().take_while(|x| x.to_bits() != 0).count();
        put_varint(buf, z as u32);
        put_varint(buf, l as u32);
        for x in &v[i..i + l] {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        i += l;
    }
}

/// Decode a kind-3 (zero-RLE) full body, cursor positioned just past the
/// kind byte. Shared verbatim by the Snapshot frame decoder and the
/// checkpoint codec, so both inherit the same hostile-input hardening.
pub(crate) fn get_full_rle(d: &mut Dec) -> Result<Vec<f32>> {
    let dim = d.varint()? as usize;
    ensure!(
        dim <= MAX_FRAME_BYTES as usize / 4,
        "snapshot RLE dim {dim} exceeds the frame cap"
    );
    // Don't trust the declared dim for the allocation: grow into it as
    // runs actually deliver.
    let mut v = Vec::with_capacity(dim.min(d.remaining()));
    while v.len() < dim {
        let z = d.varint()? as usize;
        let l = d.varint()? as usize;
        ensure!(
            z + l > 0,
            "snapshot RLE makes no progress (0,0 run pair)"
        );
        ensure!(
            z.saturating_add(l) <= dim - v.len(),
            "snapshot RLE runs overflow the declared dim {dim}"
        );
        v.extend(std::iter::repeat(0.0f32).take(z));
        let raw = d.take(4 * l)?;
        v.extend(
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
        );
    }
    Ok(v)
}

/// The checkpoint codec's master-parameter encoder: the wire-v4 lossless
/// zero-RLE full-snapshot layout, kind byte included.
pub(crate) fn put_master(buf: &mut Vec<u8>, v: &[f32]) {
    put_full_rle(buf, v);
}

/// Inverse of [`put_master`]: expects the kind byte, then the RLE body.
pub(crate) fn get_master(d: &mut Dec) -> Result<Vec<f32>> {
    let kind = d.u8()?;
    ensure!(
        kind == SNAP_FULL_RLE,
        "checkpoint master param has body kind {kind} \
         (expected {SNAP_FULL_RLE})"
    );
    get_full_rle(d)
}

// --- message encoding ---------------------------------------------------

fn put_body(buf: &mut Vec<u8>, msg: &Msg, mode: WireMode) {
    match msg {
        Msg::Hello(h) => {
            put_u32(buf, h.worker_id);
            put_u64(buf, h.seed);
            put_u32(buf, h.tau);
            put_u32(buf, h.batch);
            put_u8(buf, h.payload_mode);
            put_u32(buf, h.n_blocks);
            put_str(buf, &h.problem);
            put_u32(buf, h.config.len() as u32);
            for (k, v) in &h.config {
                put_str(buf, k);
                put_str(buf, v);
            }
            // v3: issuing shard + the block->shard routing table.
            put_u32(buf, h.shard);
            put_u32(buf, h.plan.shards.len() as u32);
            for sh in &h.plan.shards {
                put_str(buf, &sh.addr);
                put_u32(buf, sh.block_start);
                put_u32(buf, sh.block_end);
                put_u32(buf, sh.param_start);
                put_u32(buf, sh.param_end);
            }
            // v5: session generation + sampler fast-forward count.
            put_u64(buf, h.generation);
            put_u64(buf, h.resume_draws);
        }
        Msg::SnapshotRequest { have_version } => {
            put_u64(buf, *have_version);
        }
        Msg::Snapshot { version, body } => {
            put_u64(buf, *version);
            match (body, mode) {
                (SnapshotBody::Full(v), WireMode::Exact) => {
                    put_u8(buf, SNAP_FULL);
                    put_f32s(buf, v);
                }
                (SnapshotBody::Full(v), _) => put_full_rle(buf, v),
                (SnapshotBody::Delta(runs), WireMode::Exact) => {
                    put_u8(buf, SNAP_DELTA);
                    put_u32(buf, runs.len() as u32);
                    for (off, vals) in runs {
                        put_u32(buf, *off);
                        put_f32s(buf, vals);
                    }
                }
                (SnapshotBody::Delta(runs), _) => {
                    put_delta_varint(buf, runs)
                }
            }
        }
        Msg::Update {
            k_read,
            worker,
            generation,
            oracles,
        } => {
            put_u64(buf, *k_read);
            put_u32(buf, *worker);
            // v5: the sender's adopted session generation.
            put_u64(buf, *generation);
            put_u32(buf, oracles.len() as u32);
            for o in oracles {
                put_u32(buf, o.block as u32);
                put_f64(buf, o.ls);
                put_payload(buf, &o.s, mode);
            }
        }
        Msg::Shutdown => {}
        Msg::Heartbeat => {}
        Msg::Join { resumed } => {
            put_u8(buf, u8::from(*resumed));
        }
    }
}

fn get_body(tag_byte: u8, payload: &[u8]) -> Result<Msg> {
    let mut d = Dec::new(payload);
    let msg = match tag_byte {
        tag::HELLO => {
            let worker_id = d.u32()?;
            let seed = d.u64()?;
            let tau = d.u32()?;
            let batch = d.u32()?;
            let payload_mode = d.u8()?;
            let n_blocks = d.u32()?;
            let problem = d.str()?;
            let npairs = d.count(8)?;
            let mut config = Vec::with_capacity(npairs);
            for _ in 0..npairs {
                let k = d.str()?;
                let v = d.str()?;
                config.push((k, v));
            }
            let shard = d.u32()?;
            // Each plan entry is at least 20 bytes (addr length prefix
            // + four u32 spans), bounding a hostile count.
            let nshards = d.count(20)?;
            let mut shards = Vec::with_capacity(nshards);
            for _ in 0..nshards {
                let addr = d.str()?;
                shards.push(ShardInfo {
                    addr,
                    block_start: d.u32()?,
                    block_end: d.u32()?,
                    param_start: d.u32()?,
                    param_end: d.u32()?,
                });
            }
            ensure!(
                (shard as usize) < shards.len(),
                "Hello names shard {shard} of a {}-shard plan",
                shards.len()
            );
            let generation = d.u64()?;
            let resume_draws = d.u64()?;
            Msg::Hello(Hello {
                worker_id,
                seed,
                tau,
                batch,
                payload_mode,
                n_blocks,
                problem,
                config,
                shard,
                plan: ShardPlan { shards },
                generation,
                resume_draws,
            })
        }
        tag::SNAPSHOT_REQUEST => Msg::SnapshotRequest {
            have_version: d.u64()?,
        },
        tag::SNAPSHOT => {
            let version = d.u64()?;
            // Kinds 2 and 3 (v4 compressed layouts) normalize back into
            // the two in-memory bodies here, so the worker's splice code
            // never sees the wire layout.
            let body = match d.u8()? {
                SNAP_FULL => SnapshotBody::Full(d.f32s()?),
                SNAP_DELTA => {
                    let nruns = d.count(8)?;
                    let mut runs = Vec::with_capacity(nruns);
                    for _ in 0..nruns {
                        let off = d.u32()?;
                        runs.push((off, d.f32s()?));
                    }
                    SnapshotBody::Delta(runs)
                }
                SNAP_DELTA_V => {
                    let nruns = d.varint()? as usize;
                    // Each run costs >= 2 header bytes: bound a hostile
                    // count before allocating.
                    ensure!(
                        nruns.saturating_mul(2) <= d.remaining(),
                        "snapshot delta run count {nruns} exceeds the \
                         remaining payload"
                    );
                    let mut heads = Vec::with_capacity(nruns);
                    let mut prev = 0u32;
                    let mut total = 0usize;
                    for _ in 0..nruns {
                        let off = prev.wrapping_add(d.varint()?);
                        let len = d.varint()? as usize;
                        total = total.saturating_add(len);
                        prev = off;
                        heads.push((off, len));
                    }
                    ensure!(
                        total.saturating_mul(4) <= d.remaining(),
                        "snapshot delta runs ({total} values) exceed the \
                         remaining payload"
                    );
                    let mut runs = Vec::with_capacity(nruns);
                    for (off, len) in heads {
                        let raw = d.take(4 * len)?;
                        let vals: Vec<f32> = raw
                            .chunks_exact(4)
                            .map(|c| {
                                f32::from_le_bytes(c.try_into().unwrap())
                            })
                            .collect();
                        runs.push((off, vals));
                    }
                    SnapshotBody::Delta(runs)
                }
                SNAP_FULL_RLE => SnapshotBody::Full(get_full_rle(&mut d)?),
                other => bail!("unknown snapshot body tag {other}"),
            };
            Msg::Snapshot { version, body }
        }
        tag::UPDATE => {
            let k_read = d.u64()?;
            let worker = d.u32()?;
            let generation = d.u64()?;
            let count = d.count(13)?;
            let mut oracles = Vec::with_capacity(count);
            for _ in 0..count {
                let block = d.u32()? as usize;
                let ls = d.f64()?;
                let s = get_payload(&mut d)?;
                oracles.push(BlockOracle { block, s, ls });
            }
            Msg::Update {
                k_read,
                worker,
                generation,
                oracles,
            }
        }
        tag::SHUTDOWN => Msg::Shutdown,
        tag::HEARTBEAT => Msg::Heartbeat,
        tag::JOIN => Msg::Join {
            resumed: d.u8()? != 0,
        },
        other => bail!("unknown message type {other} (protocol v{VERSION})"),
    };
    // Forward compatibility: trailing bytes beyond what this version
    // consumes are permitted (additive extension); a SHORT payload is
    // rejected by the cursor above.
    Ok(msg)
}

// --- framing ------------------------------------------------------------

/// Encode `msg` as one complete frame (header + payload) into `buf`
/// (cleared first; capacity reused across calls) in [`WireMode::Exact`].
/// Returns the frame size in bytes — the unit of the `wire_*_bytes`
/// telemetry counters.
pub fn encode_frame(msg: &Msg, buf: &mut Vec<u8>) -> usize {
    encode_frame_mode(msg, buf, WireMode::Exact)
}

/// [`encode_frame`] under an explicit [`WireMode`]. Only `Update` payload
/// bodies and `Snapshot` bodies vary by mode; every control frame is
/// byte-identical across modes.
pub fn encode_frame_mode(
    msg: &Msg,
    buf: &mut Vec<u8>,
    mode: WireMode,
) -> usize {
    buf.clear();
    put_u32(buf, MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    put_u8(buf, msg.tag());
    put_u8(buf, 0); // reserved
    put_u32(buf, 0); // payload length backpatched below
    put_body(buf, msg, mode);
    let len = (buf.len() - HEADER_BYTES) as u32;
    buf[8..12].copy_from_slice(&len.to_le_bytes());
    buf.len()
}

/// Write `msg` as one frame in [`WireMode::Exact`]. Returns the bytes put
/// on the wire. `buf` is the caller's encode scratch (reused across
/// calls). Errors — without emitting anything — on a payload above
/// [`MAX_FRAME_BYTES`]: every compliant decoder would reject such a
/// frame, and sending it anyway would surface as a confusing peer-side
/// disconnect instead of this sender-side error.
pub fn write_frame(
    w: &mut impl Write,
    msg: &Msg,
    buf: &mut Vec<u8>,
) -> Result<usize> {
    write_frame_mode(w, msg, buf, WireMode::Exact)
}

/// [`write_frame`] under an explicit [`WireMode`] (the `run.wire` knob's
/// write path: worker update pushes and server snapshot answers).
pub fn write_frame_mode(
    w: &mut impl Write,
    msg: &Msg,
    buf: &mut Vec<u8>,
    mode: WireMode,
) -> Result<usize> {
    let n = encode_frame_mode(msg, buf, mode);
    ensure!(
        n - HEADER_BYTES <= MAX_FRAME_BYTES as usize,
        "refusing to send a {}-byte frame payload (cap: {MAX_FRAME_BYTES}; \
         is the parameter dimension beyond the wire protocol's design \
         range?)",
        n - HEADER_BYTES
    );
    w.write_all(buf)?;
    Ok(n)
}

/// Read one frame. Returns `Ok(None)` on a clean end-of-stream (the peer
/// closed before any header byte); errors on bad magic, an unsupported
/// version, an unknown message type, an oversized length prefix, or a
/// frame truncated mid-way.
pub fn read_frame(r: &mut impl Read) -> Result<Option<(Msg, usize)>> {
    let mut header = [0u8; HEADER_BYTES];
    // Distinguish clean EOF (no bytes at a frame boundary) from a header
    // truncated part-way through.
    let mut got = 0usize;
    while got < HEADER_BYTES {
        let n = r.read(&mut header[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("truncated frame header ({got} of {HEADER_BYTES} bytes)");
        }
        got += n;
    }
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    ensure!(
        magic == MAGIC,
        "bad frame magic {magic:#010x} (expected {MAGIC:#010x}) — not an \
         apbcfw peer?"
    );
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    ensure!(
        version == VERSION,
        "unsupported protocol version {version} (this build speaks v{VERSION})"
    );
    let tag_byte = header[6];
    let len = u32::from_le_bytes(header[8..12].try_into().unwrap());
    ensure!(
        len <= MAX_FRAME_BYTES,
        "frame payload length {len} exceeds the {MAX_FRAME_BYTES}-byte cap"
    );
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| anyhow!("truncated frame payload: {e}"))?;
    let msg = get_body(tag_byte, &payload)?;
    Ok(Some((msg, HEADER_BYTES + len as usize)))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Encode-then-decode helper over an in-memory cursor.
    fn roundtrip(msg: &Msg) -> Msg {
        let mut buf = Vec::new();
        let n = encode_frame(msg, &mut buf);
        assert_eq!(n, buf.len());
        let mut cursor: &[u8] = &buf;
        let (decoded, consumed) =
            read_frame(&mut cursor).unwrap().expect("not EOF");
        assert_eq!(consumed, n);
        assert!(cursor.is_empty(), "frame must consume itself exactly");
        decoded
    }

    #[test]
    fn roundtrips_every_message_type() {
        let msgs = [
            Msg::Hello(Hello {
                worker_id: 3,
                seed: 99,
                tau: 4,
                batch: 2,
                payload_mode: 2,
                n_blocks: 39,
                problem: "gfl".into(),
                config: vec![
                    ("gfl.d".into(), "6".into()),
                    ("run.seed".into(), "5".into()),
                ],
                shard: 1,
                plan: ShardPlan {
                    shards: vec![
                        ShardInfo {
                            addr: "127.0.0.1:7920".into(),
                            block_start: 0,
                            block_end: 20,
                            param_start: 0,
                            param_end: 120,
                        },
                        ShardInfo {
                            addr: "127.0.0.1:7921".into(),
                            block_start: 20,
                            block_end: 39,
                            param_start: 120,
                            param_end: 234,
                        },
                    ],
                },
                generation: 2,
                resume_draws: 415,
            }),
            Msg::SnapshotRequest {
                have_version: u64::MAX,
            },
            Msg::Snapshot {
                version: 17,
                body: SnapshotBody::Full(vec![1.0, -2.5, f32::MIN_POSITIVE]),
            },
            Msg::Snapshot {
                version: 18,
                body: SnapshotBody::Delta(vec![
                    (0, vec![0.5]),
                    (7, vec![1.0, 2.0]),
                ]),
            },
            Msg::Snapshot {
                version: 18,
                body: SnapshotBody::Delta(vec![]),
            },
            Msg::Update {
                k_read: 12,
                worker: 1,
                generation: 3,
                oracles: vec![
                    BlockOracle::dense(4, vec![0.0, 1.0], 0.25),
                    BlockOracle {
                        block: 9,
                        s: OraclePayload::Sparse {
                            idx: vec![0, 5],
                            val: vec![-1.0, 3.5],
                            dim: 8,
                        },
                        ls: -0.5,
                    },
                    BlockOracle {
                        block: 2,
                        s: OraclePayload::Sparse {
                            idx: vec![],
                            val: vec![],
                            dim: 8,
                        },
                        ls: 0.0,
                    },
                ],
            },
            Msg::Shutdown,
            Msg::Heartbeat,
            Msg::Join { resumed: false },
            Msg::Join { resumed: true },
        ];
        for msg in &msgs {
            assert_eq!(&roundtrip(msg), msg);
        }
    }

    #[test]
    fn v1_peer_frames_are_rejected_with_a_version_error() {
        // A v1 build writes version=1 in the header; this v5 build must
        // reject it cleanly (docs/WIRE.md §8: both roles ship in one
        // binary, so a version skew means mismatched deployments).
        let mut buf = Vec::new();
        encode_frame(&Msg::Shutdown, &mut buf);
        buf[4..6].copy_from_slice(&1u16.to_le_bytes());
        let err = read_frame(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("version 1"), "{err}");
        assert!(err.contains("v5"), "{err}");
    }

    #[test]
    fn hello_rejects_an_out_of_plan_shard_index() {
        let hello = Msg::Hello(Hello {
            worker_id: 0,
            seed: 1,
            tau: 1,
            batch: 1,
            payload_mode: 0,
            n_blocks: 4,
            problem: "gfl".into(),
            config: vec![],
            shard: 0,
            plan: ShardPlan::single("h:1".into(), 4, 16),
            generation: 0,
            resume_draws: 0,
        });
        let mut buf = Vec::new();
        encode_frame(&hello, &mut buf);
        // Corrupt the shard index (the u32 right after the config
        // pairs) to point past the one-shard plan. Counting back from
        // the end: resume_draws (8) + generation (8) + the one plan
        // entry (addr 4+3 + four u32 spans 16) + nshards (4) + shard (4).
        let shard_off = buf.len() - (8 + 8 + (4 + 3) + 16 + 4 + 4);
        buf[shard_off..shard_off + 4]
            .copy_from_slice(&9u32.to_le_bytes());
        let err = read_frame(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("shard 9"), "{err}");
    }

    #[test]
    fn update_frames_are_recognized_for_chaos_injection() {
        let mut buf = Vec::new();
        encode_frame(
            &Msg::Update {
                k_read: 0,
                worker: 0,
                generation: 0,
                oracles: vec![],
            },
            &mut buf,
        );
        assert!(frame_is_update(&buf));
        for other in [
            Msg::Shutdown,
            Msg::Heartbeat,
            Msg::Join { resumed: true },
            Msg::SnapshotRequest { have_version: 0 },
        ] {
            encode_frame(&other, &mut buf);
            assert!(!frame_is_update(&buf), "{other:?}");
        }
        assert!(!frame_is_update(&[0u8; 4]));
    }

    #[test]
    fn sparse_payload_survives_the_wire_sparse() {
        let msg = Msg::Update {
            k_read: 0,
            worker: 0,
            generation: 0,
            oracles: vec![BlockOracle {
                block: 0,
                s: OraclePayload::Sparse {
                    idx: vec![2],
                    val: vec![1.0],
                    dim: 100,
                },
                ls: 0.0,
            }],
        };
        match roundtrip(&msg) {
            Msg::Update { oracles, .. } => match &oracles[0].s {
                OraclePayload::Sparse { idx, val, dim } => {
                    assert_eq!((idx.as_slice(), val.as_slice(), *dim),
                        ([2u32].as_slice(), [1.0f32].as_slice(), 100));
                }
                other => panic!("densified on the wire: {other:?}"),
            },
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn truncation_anywhere_is_rejected_not_a_panic() {
        let msg = Msg::Update {
            k_read: 5,
            worker: 0,
            generation: 1,
            oracles: vec![BlockOracle {
                block: 1,
                s: OraclePayload::Sparse {
                    idx: vec![1, 3],
                    val: vec![0.5, -0.5],
                    dim: 6,
                },
                ls: 1.5,
            }],
        };
        let mut buf = Vec::new();
        let n = encode_frame(&msg, &mut buf);
        for cut in 1..n {
            let mut cursor: &[u8] = &buf[..cut];
            assert!(
                read_frame(&mut cursor).is_err(),
                "cut at {cut} of {n} must error"
            );
        }
        // Zero bytes is the one clean case: EOF at a frame boundary.
        let mut empty: &[u8] = &[];
        assert!(read_frame(&mut empty).unwrap().is_none());
    }

    #[test]
    fn bad_magic_version_and_type_are_rejected() {
        let mut buf = Vec::new();
        encode_frame(&Msg::Shutdown, &mut buf);

        let mut bad = buf.clone();
        bad[0] ^= 0xff;
        let err = read_frame(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");

        let mut bad = buf.clone();
        bad[4] = 0xfe; // version
        let err = read_frame(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");

        let mut bad = buf.clone();
        bad[6] = 0xee; // message type
        let err = read_frame(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(err.contains("message type"), "{err}");

        let mut bad = buf;
        bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(err.contains("cap"), "{err}");
    }

    #[test]
    fn corrupt_sparse_invariants_are_rejected() {
        // Descending indices.
        let msg = Msg::Update {
            k_read: 0,
            worker: 0,
            generation: 0,
            oracles: vec![BlockOracle {
                block: 0,
                s: OraclePayload::Sparse {
                    idx: vec![5, 2],
                    val: vec![1.0, 2.0],
                    dim: 8,
                },
                ls: 0.0,
            }],
        };
        let mut buf = Vec::new();
        encode_frame(&msg, &mut buf);
        let err = read_frame(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("ascending"), "{err}");

        // Out-of-bounds index.
        let msg = Msg::Update {
            k_read: 0,
            worker: 0,
            generation: 0,
            oracles: vec![BlockOracle {
                block: 0,
                s: OraclePayload::Sparse {
                    idx: vec![8],
                    val: vec![1.0],
                    dim: 8,
                },
                ls: 0.0,
            }],
        };
        encode_frame(&msg, &mut buf);
        let err = read_frame(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("bounds"), "{err}");
    }

    #[test]
    fn trailing_bytes_are_tolerated_for_forward_compat() {
        // A v1 decoder must accept a payload longer than it consumes
        // (additive extension by a newer minor revision).
        let mut buf = Vec::new();
        encode_frame(
            &Msg::SnapshotRequest { have_version: 7 },
            &mut buf,
        );
        buf.extend_from_slice(&[0xab, 0xcd]); // extension bytes
        let len = (buf.len() - HEADER_BYTES) as u32;
        buf[8..12].copy_from_slice(&len.to_le_bytes());
        let (msg, n) = read_frame(&mut buf.as_slice()).unwrap().unwrap();
        assert_eq!(msg, Msg::SnapshotRequest { have_version: 7 });
        assert_eq!(n, buf.len());
    }

    /// Roundtrip helper under an explicit wire mode.
    fn roundtrip_mode(msg: &Msg, mode: WireMode) -> Msg {
        let mut buf = Vec::new();
        let n = encode_frame_mode(msg, &mut buf, mode);
        assert_eq!(n, buf.len());
        let mut cursor: &[u8] = &buf;
        let (decoded, consumed) =
            read_frame(&mut cursor).unwrap().expect("not EOF");
        assert_eq!(consumed, n);
        decoded
    }

    #[test]
    fn wire_mode_parses_the_knob_vocabulary() {
        assert_eq!(WireMode::parse("exact").unwrap(), WireMode::Exact);
        assert_eq!(WireMode::parse("f16").unwrap(), WireMode::F16);
        assert_eq!(WireMode::parse("q8").unwrap(), WireMode::Q8);
        assert_eq!(WireMode::default(), WireMode::Exact);
        for mode in [WireMode::Exact, WireMode::F16, WireMode::Q8] {
            assert_eq!(WireMode::parse(mode.name()).unwrap(), mode);
        }
        let err = WireMode::parse("bogus").unwrap_err().to_string();
        assert!(err.contains("run.wire"), "{err}");
        assert!(err.contains("exact | f16 | q8"), "{err}");
    }

    #[test]
    fn f16_conversion_is_exact_on_representable_values_and_bounded() {
        // Every value with <= 10 mantissa bits and in-range exponent
        // survives the narrow-widen roundtrip exactly.
        for v in [
            0.0f32, -0.0, 1.0, -1.0, 0.5, -0.25, 2.0, 1024.0, -65504.0,
            65504.0, 0.125, 1.5, 3.140625,
        ] {
            assert_eq!(f16_to_f32(f32_to_f16(v)).to_bits(), v.to_bits(),
                "{v}");
        }
        // Non-representable values round within half-precision epsilon.
        for v in [0.1f32, -0.3, 2.7182817, 123.456, 1e-3, -7.77] {
            let back = f16_to_f32(f32_to_f16(v));
            let rel = ((back - v) / v).abs();
            assert!(rel <= 1.0 / 1024.0, "{v} -> {back} (rel {rel})");
        }
        // Overflow saturates to infinity, tiny values flush toward zero,
        // and specials keep their class.
        assert_eq!(f16_to_f32(f32_to_f16(1e9)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(-1e9)), f32::NEG_INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(1e-10)), 0.0);
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // Subnormal half range roundtrips too (2^-24 is the smallest).
        let sub = f16_to_f32(f32_to_f16(6e-8));
        assert!(sub > 0.0 && sub < 1e-7, "{sub}");
    }

    #[test]
    fn quantized_sparse_payloads_roundtrip_within_tolerance() {
        let msg = |val: Vec<f32>| Msg::Update {
            k_read: 3,
            worker: 1,
            generation: 0,
            oracles: vec![BlockOracle {
                block: 5,
                s: OraclePayload::Sparse {
                    idx: (0..val.len() as u32).collect(),
                    val,
                    dim: 64,
                },
                ls: 0.75,
            }],
        };
        let vals = vec![1.0f32, -0.5, 0.3333, 0.0, -0.0625, 0.9999];
        for mode in [WireMode::F16, WireMode::Q8] {
            match roundtrip_mode(&msg(vals.clone()), mode) {
                Msg::Update { k_read, worker, oracles, .. } => {
                    assert_eq!((k_read, worker), (3, 1));
                    match &oracles[0].s {
                        OraclePayload::Sparse { idx, val, dim } => {
                            assert_eq!(idx.len(), vals.len());
                            assert_eq!(*dim, 64);
                            let max_abs = 1.0f32;
                            // f16: 2^-11 relative; q8: half a bucket of
                            // scale/127 absolute.
                            let tol = match mode {
                                WireMode::F16 => max_abs / 1024.0,
                                _ => max_abs / 127.0,
                            };
                            for (a, b) in vals.iter().zip(val) {
                                assert!(
                                    (a - b).abs() <= tol,
                                    "{mode:?}: {a} -> {b}"
                                );
                            }
                        }
                        other => panic!("densified: {other:?}"),
                    }
                    assert_eq!(oracles[0].ls, 0.75); // ls stays exact
                }
                other => panic!("{other:?}"),
            }
        }
        // The all-zero payload ships scale 0 and decodes to exact zeros.
        match roundtrip_mode(&msg(vec![0.0, 0.0]), WireMode::Q8) {
            Msg::Update { oracles, .. } => match &oracles[0].s {
                OraclePayload::Sparse { val, .. } => {
                    assert_eq!(val, &vec![0.0, 0.0]);
                }
                other => panic!("{other:?}"),
            },
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn quantized_payloads_ship_fewer_bytes_than_exact() {
        let msg = Msg::Update {
            k_read: 0,
            worker: 0,
            generation: 0,
            oracles: vec![BlockOracle {
                block: 0,
                s: OraclePayload::Sparse {
                    idx: (0..100).collect(),
                    val: vec![0.25; 100],
                    dim: 1000,
                },
                ls: 0.0,
            }],
        };
        let mut buf = Vec::new();
        let exact = encode_frame_mode(&msg, &mut buf, WireMode::Exact);
        let f16 = encode_frame_mode(&msg, &mut buf, WireMode::F16);
        let q8 = encode_frame_mode(&msg, &mut buf, WireMode::Q8);
        assert!(f16 < exact, "f16 {f16} vs exact {exact}");
        assert!(q8 < f16, "q8 {q8} vs f16 {f16}");
    }

    #[test]
    fn exact_mode_is_byte_identical_to_the_documented_v5_body_layout() {
        // `run.wire = exact` is the pinned default: the mode-aware
        // encoder must emit exactly what the plain encoder emits, and
        // the sparse body must keep the documented v5 layout
        // (`k_read | worker | generation | count |
        // 1 | dim | nnz | idx | nval | val`, all little-endian — the v3
        // payload encoding with the v5 generation stamp after `worker`).
        let msg = Msg::Update {
            k_read: 7,
            worker: 2,
            generation: 4,
            oracles: vec![BlockOracle {
                block: 3,
                s: OraclePayload::Sparse {
                    idx: vec![1, 4],
                    val: vec![0.5, -2.0],
                    dim: 6,
                },
                ls: 1.25,
            }],
        };
        let mut plain = Vec::new();
        let mut moded = Vec::new();
        encode_frame(&msg, &mut plain);
        encode_frame_mode(&msg, &mut moded, WireMode::Exact);
        assert_eq!(plain, moded);
        // Hand-assembled v5 Update body.
        let mut body = Vec::new();
        body.extend_from_slice(&7u64.to_le_bytes()); // k_read
        body.extend_from_slice(&2u32.to_le_bytes()); // worker
        body.extend_from_slice(&4u64.to_le_bytes()); // generation (v5)
        body.extend_from_slice(&1u32.to_le_bytes()); // oracle count
        body.extend_from_slice(&3u32.to_le_bytes()); // block
        body.extend_from_slice(&1.25f64.to_le_bytes()); // ls
        body.push(1); // PAYLOAD_SPARSE
        body.extend_from_slice(&6u32.to_le_bytes()); // dim
        body.extend_from_slice(&2u32.to_le_bytes()); // nnz
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&4u32.to_le_bytes());
        body.extend_from_slice(&2u32.to_le_bytes()); // nval
        body.extend_from_slice(&0.5f32.to_le_bytes());
        body.extend_from_slice(&(-2.0f32).to_le_bytes());
        assert_eq!(&plain[HEADER_BYTES..], body.as_slice());
        // Exact snapshot bodies keep their v3 kinds too.
        let snaps = [
            Msg::Snapshot {
                version: 1,
                body: SnapshotBody::Full(vec![1.0, 0.0]),
            },
            Msg::Snapshot {
                version: 2,
                body: SnapshotBody::Delta(vec![(3, vec![0.5])]),
            },
        ];
        for (snap, kind) in snaps.iter().zip([SNAP_FULL, SNAP_DELTA]) {
            encode_frame_mode(snap, &mut moded, WireMode::Exact);
            assert_eq!(moded[HEADER_BYTES + 8], kind, "{snap:?}");
        }
    }

    #[test]
    fn compressed_snapshot_bodies_roundtrip_losslessly() {
        // Snapshots must stay lossless in every mode — workers compute
        // oracles on them. Kind 2 (varint delta) and kind 3 (zero-RLE
        // full) are exercised through the non-exact modes.
        let mut full = vec![0.0f32; 300];
        full[7] = 1.5;
        full[8] = -0.25;
        full[299] = f32::MIN_POSITIVE;
        full[100] = -0.0; // negative zero must survive bit-exactly
        let bodies = [
            SnapshotBody::Full(full.clone()),
            SnapshotBody::Full(vec![]),
            SnapshotBody::Full(vec![0.0; 64]),
            SnapshotBody::Delta(vec![
                (0, vec![0.5]),
                (7, vec![1.0, 2.0]),
                (300, vec![-1.0]),
            ]),
            SnapshotBody::Delta(vec![]),
            SnapshotBody::Delta(vec![(9, vec![])]),
        ];
        for body in &bodies {
            for mode in [WireMode::F16, WireMode::Q8] {
                let msg = Msg::Snapshot {
                    version: 21,
                    body: body.clone(),
                };
                let decoded = roundtrip_mode(&msg, mode);
                match (&decoded, &msg) {
                    (
                        Msg::Snapshot { body: got, .. },
                        Msg::Snapshot { body: want, .. },
                    ) => match (got, want) {
                        (
                            SnapshotBody::Full(g),
                            SnapshotBody::Full(w),
                        ) => {
                            let gb: Vec<u32> =
                                g.iter().map(|x| x.to_bits()).collect();
                            let wb: Vec<u32> =
                                w.iter().map(|x| x.to_bits()).collect();
                            assert_eq!(gb, wb);
                        }
                        (got, want) => assert_eq!(got, want),
                    },
                    other => panic!("{other:?}"),
                }
            }
        }
        // And the mostly-zero full body really is smaller compressed.
        let msg = Msg::Snapshot {
            version: 1,
            body: SnapshotBody::Full(full),
        };
        let mut buf = Vec::new();
        let exact = encode_frame_mode(&msg, &mut buf, WireMode::Exact);
        let rle = encode_frame_mode(&msg, &mut buf, WireMode::Q8);
        assert!(rle < exact / 4, "rle {rle} vs exact {exact}");
    }

    #[test]
    fn corrupt_compressed_snapshots_are_rejected_not_looped() {
        // A (0,0) RLE run pair makes no progress; the decoder must
        // reject it instead of spinning.
        let mut buf = Vec::new();
        encode_frame(&Msg::Heartbeat, &mut buf);
        buf.truncate(HEADER_BYTES);
        buf[6] = 3; // SNAPSHOT
        buf.extend_from_slice(&0u64.to_le_bytes()); // version
        buf.push(3); // SNAP_FULL_RLE
        buf.push(10); // dim = 10 (varint)
        buf.push(0); // zero run 0
        buf.push(0); // literal run 0
        let len = (buf.len() - HEADER_BYTES) as u32;
        buf[8..12].copy_from_slice(&len.to_le_bytes());
        let err = read_frame(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("no progress"), "{err}");
        // Runs that overflow the declared dim are rejected too.
        buf.truncate(buf.len() - 2);
        buf.push(11); // zero run 11 > dim 10
        buf.push(0);
        let len = (buf.len() - HEADER_BYTES) as u32;
        buf[8..12].copy_from_slice(&len.to_le_bytes());
        let err = read_frame(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("overflow"), "{err}");
    }

    #[test]
    fn fuzz_every_truncation_and_byte_flip_is_panic_free() {
        // The decoder-hardening pin: for a corpus of frames covering
        // every message type in every wire mode, (a) every truncation
        // yields a clean Err (cut 0 is the one clean EOF), and (b) every
        // single-byte flip either decodes or errors — never panics. The
        // sweep is deterministic: every byte position, three flip
        // patterns, no RNG.
        let corpus_msgs = [
            Msg::Hello(Hello {
                worker_id: 1,
                seed: 9,
                tau: 2,
                batch: 1,
                payload_mode: 2,
                n_blocks: 8,
                problem: "qp".into(),
                config: vec![("run.wire".into(), "q8".into())],
                shard: 0,
                plan: ShardPlan::single("h:1".into(), 8, 32),
                generation: 1,
                resume_draws: 12,
            }),
            Msg::SnapshotRequest { have_version: 3 },
            Msg::Snapshot {
                version: 5,
                body: SnapshotBody::Full(vec![0.0, 1.0, 0.0, -2.5]),
            },
            Msg::Snapshot {
                version: 6,
                body: SnapshotBody::Delta(vec![
                    (2, vec![0.5, 1.5]),
                    (9, vec![-1.0]),
                ]),
            },
            Msg::Update {
                k_read: 11,
                worker: 0,
                generation: 2,
                oracles: vec![
                    BlockOracle::dense(0, vec![1.0, -1.0], 0.5),
                    BlockOracle {
                        block: 1,
                        s: OraclePayload::Sparse {
                            idx: vec![0, 3],
                            val: vec![0.25, -0.75],
                            dim: 5,
                        },
                        ls: -0.5,
                    },
                ],
            },
            Msg::Shutdown,
            Msg::Heartbeat,
            Msg::Join { resumed: true },
        ];
        let mut corpus: Vec<Vec<u8>> = Vec::new();
        for msg in &corpus_msgs {
            for mode in [WireMode::Exact, WireMode::F16, WireMode::Q8] {
                let mut buf = Vec::new();
                encode_frame_mode(msg, &mut buf, mode);
                corpus.push(buf);
            }
        }
        for frame in &corpus {
            let n = frame.len();
            for cut in 0..n {
                let mut cursor: &[u8] = &frame[..cut];
                let got = read_frame(&mut cursor);
                if cut == 0 {
                    assert!(got.unwrap().is_none());
                } else {
                    assert!(got.is_err(), "cut {cut} of {n}");
                }
            }
            for i in 0..n {
                for pattern in [0xffu8, 0x01, 0x80] {
                    let mut bad = frame.clone();
                    bad[i] ^= pattern;
                    // Must return (a flip can still be a valid frame);
                    // a panic fails the test.
                    let _ = read_frame(&mut bad.as_slice());
                }
            }
        }
    }

    #[test]
    fn frame_sizes_reflect_payload_sparsity() {
        // The whole point of the sparse pipeline: a 1-hot vertex over a
        // large dim ships O(1) bytes where dense ships O(dim).
        let sparse = Msg::Update {
            k_read: 0,
            worker: 0,
            generation: 0,
            oracles: vec![BlockOracle {
                block: 0,
                s: OraclePayload::Sparse {
                    idx: vec![500],
                    val: vec![1.0],
                    dim: 1000,
                },
                ls: 0.0,
            }],
        };
        let mut dense_s = vec![0.0f32; 1000];
        dense_s[500] = 1.0;
        let dense = Msg::Update {
            k_read: 0,
            worker: 0,
            generation: 0,
            oracles: vec![BlockOracle::dense(0, dense_s, 0.0)],
        };
        let mut buf = Vec::new();
        let ns = encode_frame(&sparse, &mut buf);
        let nd = encode_frame(&dense, &mut buf);
        assert!(ns < 100, "sparse frame is {ns} bytes");
        assert!(nd > 4000, "dense frame is {nd} bytes");
    }
}
