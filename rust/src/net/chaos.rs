//! Wire-level fault injection: the `run.chaos` knob.
//!
//! [`ChaosStream`] wraps a worker's transport stream and injects the
//! failure modes the paper's robustness story (§2.3/§3.4) and the
//! unbounded-delay analysis of Peng–Xu–Yan–Yin (arXiv:1612.04425) care
//! about, so the Fig 3 straggler study can be replayed over real sockets
//! instead of the in-process `run.straggler` simulation:
//!
//! - **delay** — before an outbound `Update` frame, sleep a sampled
//!   duration (fixed, or heavy-tailed Pareto with shape 2 — infinite
//!   variance, finite mean — parameterized by its mean as in the paper's
//!   delay experiments). The server's `delay_sum`/`delay_max` counters
//!   then measure the *induced iteration staleness*, the x-axis of the
//!   replay.
//! - **drop** — swallow an outbound `Update` frame whole (the oracle work
//!   is lost in flight; the server simply never ingests it).
//! - **reorder** — hold an outbound `Update` frame back (up to a bounded
//!   buffer depth) and release it after a *later* frame goes out, so
//!   frames arrive out of send order — the delayed-update analogue of
//!   network reordering. Any subsequent write releases the buffer: a
//!   later update flushes held frames *after* itself (true reordering),
//!   while a control frame drains them *ahead* of itself, so a clean
//!   shutdown never silently discards completed oracle work. Only frames
//!   held at an abrupt close (socket error, injected disconnect) are
//!   lost in flight, exactly like a drop.
//! - **disconnect** — abruptly fail an outbound `Update` write, ending
//!   the session mid-run; a resilient worker then reconnects with backoff
//!   and rejoins the fleet under a fresh server-issued id.
//!
//! Injection is frame-atomic and applies only to `Update` frames: control
//! messages (handshake, snapshot requests, heartbeats) are never delayed,
//! dropped, or held themselves — though writing one first drains any
//! reorder-held updates, preserving the invariant that a frame the worker
//! believes it sent before a graceful close actually reached the wire.
//! Received-direction delay (`rx-delay`) sleeps on the read path instead
//! (per read call, i.e. roughly twice per frame: header then payload).
//!
//! With `run.chaos` unset (or `none`) the worker never constructs this
//! wrapper at all — the no-chaos path is bit-identical to the plain
//! transport.

use crate::util::rng::Pcg64;
use anyhow::{anyhow, bail, ensure, Result};
use std::io::{Read, Write};
use std::time::Duration;

/// Upper bound on one injected sleep, so a deep Pareto tail stalls a
/// worker (and trips liveness) without freezing a test run forever.
const MAX_SLEEP_MS: f64 = 30_000.0;

/// Rng stream selector for a worker's chaos schedule. Offset far beyond
/// the block-sampling streams ([`super::rng_stream_for`] = 2 + id) so
/// fault injection never perturbs the optimization's random choices, and
/// keyed by the server-issued worker id so every session — including a
/// joiner's — replays its own deterministic fault schedule.
pub fn chaos_rng_stream(worker_id: u32) -> u64 {
    1_000_003 + u64::from(worker_id)
}

/// An injected-delay distribution over milliseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DelayProfile {
    /// Always exactly this many milliseconds.
    FixedMs(f64),
    /// Pareto with shape 2 and scale `mean/2`, so the expectation is
    /// `mean` ms and the variance is infinite — the paper's heavy-tailed
    /// straggler profile.
    ParetoMeanMs(f64),
}

impl DelayProfile {
    /// Sample one delay in milliseconds (capped at [`MAX_SLEEP_MS`]).
    pub fn sample_ms(&self, rng: &mut Pcg64) -> f64 {
        let ms = match *self {
            DelayProfile::FixedMs(ms) => ms,
            DelayProfile::ParetoMeanMs(mean) => rng.pareto(2.0, mean / 2.0),
        };
        ms.min(MAX_SLEEP_MS)
    }
}

/// Parsed `run.chaos` knob: which faults to inject, with what
/// probabilities. The default (no ops) injects nothing.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ChaosSpec {
    /// Delay outbound `Update` frames: `(profile, probability)`.
    pub tx_delay: Option<(DelayProfile, f64)>,
    /// Delay the read path: `(profile, probability)` per read call.
    pub rx_delay: Option<(DelayProfile, f64)>,
    /// Probability an outbound `Update` frame is swallowed whole.
    pub drop_p: f64,
    /// Hold-and-release reordering of outbound `Update` frames:
    /// `(probability, max held frames)`. A rolled frame is buffered (up
    /// to the depth) and released only after a later update is written.
    pub reorder: Option<(f64, usize)>,
    /// Probability an outbound `Update` write fails abruptly, ending the
    /// session (a resilient worker reconnects and rejoins).
    pub disconnect_p: f64,
    /// `crash:K` — a **server-side** fault: the serve loop aborts its
    /// shard after exactly K applied updates (generation 0 only, so a
    /// restored loop cannot re-crash), making the checkpoint/restore
    /// path deterministically CI-testable without timing races. Workers
    /// ignore this op entirely (see [`ChaosSpec::is_noop`]).
    pub crash: Option<u64>,
}

impl ChaosSpec {
    /// True when no fault is ever injected **on the stream** — the
    /// worker skips the [`ChaosStream`] wrapper entirely in that case.
    /// Deliberately ignores `crash`: it is a server-loop fault, not a
    /// stream fault, so `run.chaos = crash:K` alone keeps the worker
    /// transport bit-identical to the no-chaos path.
    pub fn is_noop(&self) -> bool {
        self.tx_delay.is_none()
            && self.rx_delay.is_none()
            && self.drop_p == 0.0
            && self.reorder.is_none()
            && self.disconnect_p == 0.0
    }

    /// Parse the `run.chaos` grammar:
    ///
    /// ```text
    /// none | op[,op ...]
    /// op := delay:fixed:MS:P | delay:pareto:MEAN_MS:P
    ///     | rx-delay:fixed:MS:P | rx-delay:pareto:MEAN_MS:P
    ///     | drop:P | reorder:P:DEPTH | disconnect:P | crash:K
    /// ```
    ///
    /// Probabilities must lie in `[0, 1]`, durations must be finite and
    /// non-negative, `DEPTH` (the reorder hold-buffer bound) and `K`
    /// (the server-side crash point, in applied updates) must be
    /// positive integers, and each op may appear at most once.
    pub fn parse(text: &str) -> Result<ChaosSpec> {
        let text = text.trim();
        let mut spec = ChaosSpec::default();
        if text.is_empty() || text == "none" {
            return Ok(spec);
        }
        let (mut saw_drop, mut saw_disc) = (false, false);
        for op in text.split(',') {
            let op = op.trim();
            if let Some(rest) = op.strip_prefix("delay:") {
                ensure!(
                    spec.tx_delay.is_none(),
                    "run.chaos: duplicate delay op in {text:?}"
                );
                spec.tx_delay = Some(parse_delay_op(op, rest)?);
            } else if let Some(rest) = op.strip_prefix("rx-delay:") {
                ensure!(
                    spec.rx_delay.is_none(),
                    "run.chaos: duplicate rx-delay op in {text:?}"
                );
                spec.rx_delay = Some(parse_delay_op(op, rest)?);
            } else if let Some(p) = op.strip_prefix("drop:") {
                ensure!(!saw_drop, "run.chaos: duplicate drop op in {text:?}");
                saw_drop = true;
                spec.drop_p = parse_prob(op, p)?;
            } else if let Some(rest) = op.strip_prefix("reorder:") {
                ensure!(
                    spec.reorder.is_none(),
                    "run.chaos: duplicate reorder op in {text:?}"
                );
                let (p_text, depth_text) =
                    rest.split_once(':').ok_or_else(|| {
                        anyhow!(
                            "run.chaos: {op:?}: expected reorder:P:DEPTH"
                        )
                    })?;
                let p = parse_prob(op, p_text)?;
                let depth: usize =
                    depth_text.trim().parse().map_err(|_| {
                        anyhow!("run.chaos: {op:?}: bad hold depth")
                    })?;
                ensure!(
                    depth >= 1,
                    "run.chaos: {op:?}: hold depth must be >= 1"
                );
                spec.reorder = Some((p, depth));
            } else if let Some(p) = op.strip_prefix("disconnect:") {
                ensure!(
                    !saw_disc,
                    "run.chaos: duplicate disconnect op in {text:?}"
                );
                saw_disc = true;
                spec.disconnect_p = parse_prob(op, p)?;
            } else if let Some(k_text) = op.strip_prefix("crash:") {
                ensure!(
                    spec.crash.is_none(),
                    "run.chaos: duplicate crash op in {text:?}"
                );
                let k: u64 = k_text.trim().parse().map_err(|_| {
                    anyhow!("run.chaos: {op:?}: bad crash point (crash:K \
                             with K a positive integer of applied updates)")
                })?;
                ensure!(
                    k >= 1,
                    "run.chaos: {op:?}: crash point must be >= 1"
                );
                spec.crash = Some(k);
            } else {
                bail!(
                    "run.chaos: unknown op {op:?} (expected delay:fixed:MS:P \
                     | delay:pareto:MEAN_MS:P | rx-delay:... | drop:P | \
                     reorder:P:DEPTH | disconnect:P | crash:K, \
                     comma-separated)"
                );
            }
        }
        Ok(spec)
    }
}

/// Parse the `DIST:MS:P` tail of a delay op.
fn parse_delay_op(op: &str, rest: &str) -> Result<(DelayProfile, f64)> {
    let mut parts = rest.splitn(3, ':');
    let dist = parts
        .next()
        .ok_or_else(|| anyhow!("run.chaos: {op:?}: missing distribution"))?;
    let ms: f64 = parts
        .next()
        .ok_or_else(|| anyhow!("run.chaos: {op:?}: missing milliseconds"))?
        .trim()
        .parse()
        .map_err(|_| anyhow!("run.chaos: {op:?}: bad milliseconds"))?;
    ensure!(
        ms.is_finite() && ms >= 0.0,
        "run.chaos: {op:?}: milliseconds must be finite and >= 0"
    );
    let p = parse_prob(
        op,
        parts
            .next()
            .ok_or_else(|| anyhow!("run.chaos: {op:?}: missing probability"))?,
    )?;
    let profile = match dist {
        "fixed" => DelayProfile::FixedMs(ms),
        "pareto" => DelayProfile::ParetoMeanMs(ms),
        other => bail!(
            "run.chaos: {op:?}: unknown distribution {other:?} \
             (fixed | pareto)"
        ),
    };
    Ok((profile, p))
}

/// Parse and range-check one probability field.
fn parse_prob(op: &str, text: &str) -> Result<f64> {
    let p: f64 = text
        .trim()
        .parse()
        .map_err(|_| anyhow!("run.chaos: {op:?}: bad probability"))?;
    ensure!(
        (0.0..=1.0).contains(&p),
        "run.chaos: {op:?}: probability {p} outside [0, 1]"
    );
    Ok(p)
}

/// A `Read + Write` stream wrapper injecting the faults of a
/// [`ChaosSpec`], deterministically driven by its own rng stream (so a
/// seeded chaos run replays the same fault schedule).
pub struct ChaosStream<S> {
    inner: S,
    spec: ChaosSpec,
    rng: Pcg64,
    /// Update frames held back by the reorder op, oldest first. Released
    /// (in held order) right *after* a later update frame is written, or
    /// right *before* any control frame goes out; only frames still here
    /// at an abrupt close are lost in flight.
    held: Vec<Vec<u8>>,
}

impl<S> ChaosStream<S> {
    /// Wrap `inner`. `rng` should come from a stream disjoint from the
    /// block-sampling streams (see [`chaos_rng_stream`]).
    pub fn new(inner: S, spec: ChaosSpec, rng: Pcg64) -> Self {
        Self {
            inner,
            spec,
            rng,
            held: Vec::new(),
        }
    }

    /// The wrapped transport. Chaos never hides the stream's own knobs —
    /// the worker reaches through here to arm read timeouts for
    /// heartbeat-while-pulling.
    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    fn roll(&mut self, p: f64) -> bool {
        p > 0.0 && self.rng.uniform() < p
    }

    fn sleep_sampled(&mut self, profile: DelayProfile) {
        let ms = profile.sample_ms(&mut self.rng);
        if ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(ms / 1000.0));
        }
    }
}

impl<S: Read> Read for ChaosStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if let Some((profile, p)) = self.spec.rx_delay {
            if self.roll(p) {
                self.sleep_sampled(profile);
            }
        }
        self.inner.read(buf)
    }
}

impl<S: Write> Write for ChaosStream<S> {
    /// Frame-atomic injection: `super::wire::write_frame` hands the whole
    /// encoded frame to one `write` call, and this impl always consumes
    /// the full buffer (inner writes go through `write_all`), so a fault
    /// either affects a complete `Update` frame or nothing.
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if super::wire::frame_is_update(buf) {
            if self.roll(self.spec.disconnect_p) {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::ConnectionReset,
                    "chaos: injected disconnect",
                ));
            }
            if self.roll(self.spec.drop_p) {
                return Ok(buf.len()); // swallowed in flight
            }
            if let Some((p, depth)) = self.spec.reorder {
                if self.held.len() < depth && self.roll(p) {
                    // Hold this frame back; the next write of any kind
                    // releases it (lost only at an abrupt close — the
                    // crash-with-frames-in-flight case).
                    self.held.push(buf.to_vec());
                    return Ok(buf.len());
                }
            }
            if let Some((profile, p)) = self.spec.tx_delay {
                if self.roll(p) {
                    self.sleep_sampled(profile);
                }
            }
            self.inner.write_all(buf)?;
            // A later update went out: release everything held, in held
            // order — the wire now carries the frames out of send order.
            for frame in std::mem::take(&mut self.held) {
                self.inner.write_all(&frame)?;
            }
            return Ok(buf.len());
        }
        // Control frame: drain any reorder-held updates *ahead* of it.
        // A worker's last writes before a graceful close are control
        // frames (heartbeat, snapshot request); without this drain the
        // hold buffer would silently discard completed oracle work that
        // the worker believes it already sent — a loss the reorder op
        // never advertised (drops are `drop:P`'s job).
        for frame in std::mem::take(&mut self.held) {
            self.inner.write_all(&frame)?;
        }
        self.inner.write_all(buf)?;
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::{self, Msg};

    #[test]
    fn parse_grammar_accepts_every_op() {
        assert!(ChaosSpec::parse("none").unwrap().is_noop());
        assert!(ChaosSpec::parse("").unwrap().is_noop());
        let spec = ChaosSpec::parse(
            "delay:pareto:30:0.5, rx-delay:fixed:2:1.0, drop:0.1, \
             reorder:0.2:4, disconnect:0.05, crash:40",
        )
        .unwrap();
        assert_eq!(
            spec.tx_delay,
            Some((DelayProfile::ParetoMeanMs(30.0), 0.5))
        );
        assert_eq!(spec.rx_delay, Some((DelayProfile::FixedMs(2.0), 1.0)));
        assert_eq!(spec.drop_p, 0.1);
        assert_eq!(spec.reorder, Some((0.2, 4)));
        assert_eq!(spec.disconnect_p, 0.05);
        assert_eq!(spec.crash, Some(40));
        assert!(!spec.is_noop());
        assert!(!ChaosSpec::parse("reorder:1.0:1").unwrap().is_noop());
        // crash is a server-loop fault, not a stream fault: on its own it
        // must keep the worker transport unwrapped (bit-identical path).
        assert!(ChaosSpec::parse("crash:7").unwrap().is_noop());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        for bad in [
            "bogus",
            "drop:1.5",
            "drop:-0.1",
            "disconnect:x",
            "delay:pareto:30",
            "delay:uniform:3:0.5",
            "delay:fixed:-1:0.5",
            "delay:fixed:inf:0.5",
            "drop:0.1,drop:0.2",
            "delay:fixed:1:0.1,delay:fixed:2:0.2",
            "reorder:0.5",
            "reorder:0.5:0",
            "reorder:1.5:2",
            "reorder:0.5:two",
            "reorder:0.5:2,reorder:0.1:1",
            "crash:0",
            "crash:-3",
            "crash:soon",
            "crash:",
            "crash:2,crash:5",
        ] {
            assert!(ChaosSpec::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn drop_swallows_update_frames_but_not_control_frames() {
        let spec = ChaosSpec::parse("drop:1.0").unwrap();
        let mut s =
            ChaosStream::new(Vec::<u8>::new(), spec, Pcg64::seeded(7));
        let mut buf = Vec::new();
        let mut scratch = Vec::new();
        // Update frame: swallowed whole — nothing reaches the inner stream.
        let n = wire::write_frame(
            &mut s,
            &Msg::Update {
                k_read: 0,
                worker: 0,
                generation: 0,
                oracles: vec![],
            },
            &mut scratch,
        )
        .unwrap();
        assert!(n > 0);
        assert!(s.inner.is_empty(), "update frame must be dropped");
        // Control frame: passes through untouched.
        wire::encode_frame(&Msg::Heartbeat, &mut buf);
        let hb_len = buf.len();
        wire::write_frame(&mut s, &Msg::Heartbeat, &mut scratch).unwrap();
        assert_eq!(s.inner.len(), hb_len);
    }

    #[test]
    fn reorder_holds_updates_and_releases_them_out_of_order() {
        // P=1, depth=2: U1 and U2 are held; U3 finds the buffer full, is
        // written through, and flushes the held frames after it — wire
        // order U3, U1, U2.
        let spec = ChaosSpec::parse("reorder:1.0:2").unwrap();
        let mut s =
            ChaosStream::new(Vec::<u8>::new(), spec, Pcg64::seeded(7));
        let mut scratch = Vec::new();
        for k in 1..=3u64 {
            wire::write_frame(
                &mut s,
                &Msg::Update {
                    k_read: k,
                    worker: 0,
                    generation: 0,
                    oracles: vec![],
                },
                &mut scratch,
            )
            .unwrap();
        }
        // Control frames pass straight through (the hold buffer is
        // already empty here; the drain-on-control case has its own
        // test below).
        wire::write_frame(&mut s, &Msg::Heartbeat, &mut scratch).unwrap();
        let mut wire_order = Vec::new();
        let mut cursor = s.inner.as_slice();
        while let Some((msg, _)) = wire::read_frame(&mut cursor).unwrap() {
            match msg {
                Msg::Update { k_read, .. } => wire_order.push(k_read),
                Msg::Heartbeat => wire_order.push(99),
                other => panic!("unexpected frame {other:?}"),
            }
        }
        assert_eq!(wire_order, vec![3, 1, 2, 99]);
        assert!(s.held.is_empty(), "release must empty the hold buffer");
    }

    #[test]
    fn control_frames_drain_held_updates_ahead_of_themselves() {
        // P=1, depth=4: U1 and U2 are both held. A heartbeat (any
        // non-update frame) must push them onto the wire *before*
        // itself — a graceful close never strands completed work in the
        // hold buffer.
        let spec = ChaosSpec::parse("reorder:1.0:4").unwrap();
        let mut s =
            ChaosStream::new(Vec::<u8>::new(), spec, Pcg64::seeded(7));
        let mut scratch = Vec::new();
        for k in 1..=2u64 {
            wire::write_frame(
                &mut s,
                &Msg::Update {
                    k_read: k,
                    worker: 0,
                    generation: 0,
                    oracles: vec![],
                },
                &mut scratch,
            )
            .unwrap();
        }
        assert!(s.inner.is_empty(), "both updates must be held");
        assert_eq!(s.held.len(), 2);
        wire::write_frame(&mut s, &Msg::Heartbeat, &mut scratch).unwrap();
        assert!(s.held.is_empty(), "control write must drain the buffer");
        let mut wire_order = Vec::new();
        let mut cursor = s.inner.as_slice();
        while let Some((msg, _)) = wire::read_frame(&mut cursor).unwrap() {
            match msg {
                Msg::Update { k_read, .. } => wire_order.push(k_read),
                Msg::Heartbeat => wire_order.push(99),
                other => panic!("unexpected frame {other:?}"),
            }
        }
        assert_eq!(
            wire_order,
            vec![1, 2, 99],
            "held updates must precede the control frame"
        );
    }

    #[test]
    fn disconnect_fails_the_update_write() {
        let spec = ChaosSpec::parse("disconnect:1.0").unwrap();
        let mut s =
            ChaosStream::new(Vec::<u8>::new(), spec, Pcg64::seeded(7));
        let mut scratch = Vec::new();
        let err = wire::write_frame(
            &mut s,
            &Msg::Update {
                k_read: 0,
                worker: 0,
                generation: 0,
                oracles: vec![],
            },
            &mut scratch,
        );
        assert!(err.is_err());
        // Control frames still flow (the session code decides to hang up).
        assert!(wire::write_frame(&mut s, &Msg::Heartbeat, &mut scratch)
            .is_ok());
    }

    #[test]
    fn read_passes_through_and_zero_prob_is_noop_schedule() {
        let spec = ChaosSpec::parse("rx-delay:fixed:0:1.0").unwrap();
        let data = vec![1u8, 2, 3];
        let mut s =
            ChaosStream::new(data.as_slice(), spec, Pcg64::seeded(7));
        let mut out = [0u8; 3];
        std::io::Read::read_exact(&mut s, &mut out).unwrap();
        assert_eq!(out, [1, 2, 3]);
    }

    #[test]
    fn pareto_profile_has_the_requested_mean() {
        let mut rng = Pcg64::seeded(9);
        let profile = DelayProfile::ParetoMeanMs(10.0);
        let n = 200_000;
        let mean = (0..n).map(|_| profile.sample_ms(&mut rng)).sum::<f64>()
            / n as f64;
        assert!((mean - 10.0).abs() < 0.5, "mean={mean}");
        assert!((0..100).all(|_| profile.sample_ms(&mut rng) >= 5.0));
    }
}
