//! Durable per-shard serve-loop checkpoints — the crash-recovery
//! substrate of the serve role.
//!
//! A [`Checkpoint`] freezes everything a shard's serve loop needs to
//! resume mid-run as if the crash never happened: the run fingerprint
//! (so a checkpoint can never be restored into a *different* run), the
//! session generation, the applied-update count `k`, the master
//! parameter (persisted with the wire-v4 lossless zero-RLE snapshot
//! encoder, so the restored param is bit-exact by construction), the
//! gap-EMA estimate, the convergence trace so far, a full
//! [`CounterSnapshot`], and the problem's opaque durable server state
//! (e.g. the SSVM dual bookkeeping).
//!
//! The on-disk format is versioned, CRC-checked, and written atomically
//! — encode into a sibling temp file, `fsync` it, `rename` over the
//! final path, `fsync` the directory — so a crash *during* a checkpoint
//! write leaves the previous checkpoint intact, and a torn write can
//! never be mistaken for a valid one. Decoding reuses the wire codec's
//! hardened [`Dec`] cursor: truncation, bit flips, hostile counts and
//! CRC damage all degrade to clean errors, never panics, and the
//! restore entry point ([`load_for_restore`]) collapses every failure
//! to a logged fresh start — a corrupt checkpoint must not be able to
//! brick a serve.

use super::shard::ShardPlan;
use super::wire::{self, Dec};
use crate::util::metrics::{CounterSnapshot, Sample, Trace};
use anyhow::{ensure, Context, Result};
use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};

/// On-disk magic of a checkpoint file ("apfw checkpoint").
const MAGIC: &[u8; 4] = b"apck";

/// Checkpoint format version. Bumped on any layout change; a restored
/// server only ever accepts its own version (no cross-version decode).
/// v2 extended the counter block from 21 to 24 fields (the adaptive
/// telemetry counters).
const VERSION: u16 = 2;

/// Hard cap on a checkpoint file a decoder will even look at, sized to
/// the wire frame cap (the master param must fit in a Snapshot frame
/// anyway, and nothing else in the file comes close).
const MAX_CHECKPOINT_BYTES: u64 = super::wire::MAX_FRAME_BYTES as u64;

/// Everything a shard serve loop persists per checkpoint and needs back
/// on restore. Field order mirrors the on-disk layout (§ format below).
///
/// On-disk layout (little-endian throughout):
///
/// ```text
/// magic "apck" | version u16 | fingerprint u64 | shard u32
/// generation u64 | k u64 | gap_estimate f64
/// master: wire-v4 full-snapshot body (kind byte + zero-RLE runs)
/// samples: count u32, then per sample
///     iter u64 | oracle_calls u64 | elapsed_s f64 | objective f64 | gap f64
/// counters: 24 x u64 (CounterSnapshot fields in declaration order)
/// server_state: len u32 | bytes
/// crc32 u32 over every preceding byte (IEEE, reflected)
/// ```
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Run identity: [`fingerprint`] over the Hello config pairs and the
    /// session [`ShardPlan`]. A checkpoint whose fingerprint does not
    /// match the restoring run is rejected (fresh start) — restoring
    /// across different problems, knobs, or shard layouts would corrupt
    /// the solve silently.
    pub fingerprint: u64,
    /// The shard this checkpoint belongs to.
    pub shard: u32,
    /// Session generation the checkpoint was taken in. A restore resumes
    /// at `generation + 1`, which is what lets the apply core fence
    /// pre-crash in-flight updates.
    pub generation: u64,
    /// Applied-update count (server iteration) at the checkpoint.
    pub k: u64,
    /// The serve loop's duality-gap EMA at the checkpoint.
    pub gap_estimate: f64,
    /// The shard's master parameter (its param span), bit-exact.
    pub master: Vec<f32>,
    /// Convergence samples recorded up to the checkpoint.
    pub samples: Vec<Sample>,
    /// Counter snapshot at the checkpoint, pre-loaded into the restored
    /// loop's counters so fleet/TX telemetry spans the whole run.
    pub counters: CounterSnapshot,
    /// The problem's opaque durable server state
    /// ([`crate::problems::Problem::checkpoint_server_state`]); empty
    /// for stateless problems.
    pub server_state: Vec<u8>,
}

/// Config keys the fingerprint deliberately ignores: the operational
/// knobs a restarted coordinator legitimately changes without changing
/// *which run* it is resuming. `--restore` itself lowers to
/// `run.restore` (a restart would self-defeat if hashed), the checkpoint
/// knobs only say *how* to persist, and the wall-clock budget / liveness
/// windows / fault injection shape the schedule, not the identity of the
/// applied-update sequence being resumed. Everything else — problem
/// shape, seed, tau, batch, payload/wire modes, epoch budget — stays in
/// the hash, so a checkpoint from a *mathematically* different run is
/// still refused.
const FINGERPRINT_EXCLUDED_KEYS: &[&str] = &[
    "run.restore",
    "run.checkpoint_dir",
    "run.checkpoint_every",
    "run.max_secs",
    "run.liveness_ms",
    "run.accept_timeout_secs",
    "run.chaos",
];

/// FNV-1a 64 run fingerprint over the handshake config pairs and the
/// session [`ShardPlan`] — exactly the inputs that determine whether two
/// serve sessions are "the same run" for restore purposes. Deliberately
/// excludes anything per-session (generation, counters) and the
/// operational knobs in [`FINGERPRINT_EXCLUDED_KEYS`]: a restarted
/// server with equivalent config and plan must produce the identical
/// fingerprint.
pub fn fingerprint(
    config_pairs: &[(String, String)],
    plan: &ShardPlan,
) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= u64::from(b);
            h = h.wrapping_mul(PRIME);
        }
        // Field separator outside the byte alphabet boundary, so
        // ("ab","c") and ("a","bc") cannot collide by concatenation.
        h ^= 0xff;
        h = h.wrapping_mul(PRIME);
    };
    for (k, v) in config_pairs {
        if FINGERPRINT_EXCLUDED_KEYS.contains(&k.as_str()) {
            continue;
        }
        eat(k.as_bytes());
        eat(v.as_bytes());
    }
    for s in &plan.shards {
        eat(s.addr.as_bytes());
        eat(&s.block_start.to_le_bytes());
        eat(&s.block_end.to_le_bytes());
        eat(&s.param_start.to_le_bytes());
        eat(&s.param_end.to_le_bytes());
    }
    h
}

/// CRC-32 (IEEE 802.3, reflected, init/final-xor `0xFFFF_FFFF`) — the
/// same checksum gzip and PNG use, bitwise so no table needs baking.
fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xffff_ffffu32;
    for &b in data {
        crc ^= u32::from(b);
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xedb8_8320 & mask);
        }
    }
    !crc
}

/// The checkpoint file path for `shard` under `dir`.
pub fn shard_path(dir: &Path, shard: usize) -> PathBuf {
    dir.join(format!("shard-{shard}.ckpt"))
}

impl Checkpoint {
    /// Serialize to the documented on-disk layout, CRC trailer included.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(
            128 + 4 * self.master.len() + self.server_state.len(),
        );
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.fingerprint.to_le_bytes());
        buf.extend_from_slice(&self.shard.to_le_bytes());
        buf.extend_from_slice(&self.generation.to_le_bytes());
        buf.extend_from_slice(&self.k.to_le_bytes());
        buf.extend_from_slice(&self.gap_estimate.to_le_bytes());
        wire::put_master(&mut buf, &self.master);
        buf.extend_from_slice(&(self.samples.len() as u32).to_le_bytes());
        for s in &self.samples {
            buf.extend_from_slice(&(s.iter as u64).to_le_bytes());
            buf.extend_from_slice(&s.oracle_calls.to_le_bytes());
            buf.extend_from_slice(&s.elapsed_s.to_le_bytes());
            buf.extend_from_slice(&s.objective.to_le_bytes());
            buf.extend_from_slice(&s.gap.to_le_bytes());
        }
        for c in counter_fields(&self.counters) {
            buf.extend_from_slice(&c.to_le_bytes());
        }
        buf.extend_from_slice(
            &(self.server_state.len() as u32).to_le_bytes(),
        );
        buf.extend_from_slice(&self.server_state);
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    /// Decode and validate one checkpoint file image. Every failure mode
    /// — wrong magic/version, truncation anywhere, hostile counts, CRC
    /// mismatch, trailing garbage — is a clean `Err`, never a panic
    /// (pinned by the corpus sweep in this module's tests).
    pub fn decode(raw: &[u8]) -> Result<Checkpoint> {
        ensure!(
            raw.len() >= MAGIC.len() + 2 + 4,
            "checkpoint file is too short ({} bytes)",
            raw.len()
        );
        // CRC first: any bit flip anywhere fails here with one message,
        // so the structural decode below only ever sees self-consistent
        // damage (truncation of the CRC-covered image itself).
        let (body, trailer) = raw.split_at(raw.len() - 4);
        let stored = u32::from_le_bytes(trailer.try_into().unwrap());
        let computed = crc32(body);
        ensure!(
            stored == computed,
            "checkpoint CRC mismatch (stored {stored:#010x}, computed \
             {computed:#010x}) — file is corrupt or torn"
        );
        let mut d = Dec::new(body);
        let magic = d.take(4)?;
        ensure!(
            magic == MAGIC,
            "not a checkpoint file (magic {magic:02x?})"
        );
        let version = u16::from_le_bytes(d.take(2)?.try_into().unwrap());
        ensure!(
            version == VERSION,
            "checkpoint format v{version} (this build reads only \
             v{VERSION})"
        );
        let fingerprint = d.u64()?;
        let shard = d.u32()?;
        let generation = d.u64()?;
        let k = d.u64()?;
        let gap_estimate = d.f64()?;
        let master = wire::get_master(&mut d)?;
        let n_samples = d.count(40)?;
        let mut samples = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            samples.push(Sample {
                iter: d.u64()? as usize,
                oracle_calls: d.u64()?,
                elapsed_s: d.f64()?,
                objective: d.f64()?,
                gap: d.f64()?,
            });
        }
        let mut counters = CounterSnapshot::default();
        {
            let fields = counter_fields_mut(&mut counters);
            for f in fields {
                *f = d.u64()?;
            }
        }
        let state_len = d.count(1)?;
        let server_state = d.take(state_len)?.to_vec();
        ensure!(
            d.remaining() == 0,
            "checkpoint has {} trailing bytes after the server state",
            d.remaining()
        );
        Ok(Checkpoint {
            fingerprint,
            shard,
            generation,
            k,
            gap_estimate,
            master,
            samples,
            counters,
            server_state,
        })
    }

    /// Rebuild a [`Trace`] from the persisted samples.
    pub fn trace(&self) -> Trace {
        Trace {
            samples: self.samples.clone(),
        }
    }

    /// Write this checkpoint durably and atomically under `dir` (created
    /// if missing): encode into `shard-<s>.ckpt.tmp`, `fsync`, `rename`
    /// over `shard-<s>.ckpt`, then `fsync` the directory so the rename
    /// itself survives a crash. Readers therefore only ever observe the
    /// previous complete checkpoint or the new complete one.
    pub fn write_atomic(&self, dir: &Path) -> Result<()> {
        fs::create_dir_all(dir).with_context(|| {
            format!("creating checkpoint dir {}", dir.display())
        })?;
        let finale = shard_path(dir, self.shard as usize);
        let tmp = finale.with_extension("ckpt.tmp");
        let image = self.encode();
        {
            let mut f = File::create(&tmp).with_context(|| {
                format!("creating {}", tmp.display())
            })?;
            f.write_all(&image)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, &finale).with_context(|| {
            format!("renaming {} -> {}", tmp.display(), finale.display())
        })?;
        // Persist the rename: fsync the containing directory.
        if let Ok(d) = File::open(dir) {
            d.sync_all().ok();
        }
        Ok(())
    }

    /// Load and fully validate shard `shard`'s checkpoint from `dir`.
    /// `Ok(None)` when no file exists (a fresh run); `Err` on any decode
    /// or validation failure.
    pub fn load(dir: &Path, shard: usize) -> Result<Option<Checkpoint>> {
        let path = shard_path(dir, shard);
        let meta = match fs::metadata(&path) {
            Ok(m) => m,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(None)
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("statting {}", path.display())
                })
            }
        };
        ensure!(
            meta.len() <= MAX_CHECKPOINT_BYTES,
            "checkpoint {} is {} bytes (cap {MAX_CHECKPOINT_BYTES})",
            path.display(),
            meta.len()
        );
        let raw = fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let ck = Checkpoint::decode(&raw)
            .with_context(|| format!("decoding {}", path.display()))?;
        ensure!(
            ck.shard as usize == shard,
            "checkpoint {} is for shard {} (expected {shard})",
            path.display(),
            ck.shard
        );
        Ok(Some(ck))
    }
}

/// Restore entry point for the serve loop: load shard `shard`'s
/// checkpoint and accept it only if it carries `fingerprint`. EVERY
/// failure — no file, truncation, corruption, CRC damage, a checkpoint
/// from a different run — collapses to `None` with one log line: the
/// fresh-start fallback. Restore can improve a run; it must never be
/// able to abort one.
pub fn load_for_restore(
    dir: &Path,
    shard: usize,
    fingerprint: u64,
) -> Option<Checkpoint> {
    match Checkpoint::load(dir, shard) {
        Ok(Some(ck)) if ck.fingerprint == fingerprint => Some(ck),
        Ok(Some(ck)) => {
            eprintln!(
                "[serve] shard {shard}: checkpoint fingerprint \
                 {:#018x} does not match this run ({fingerprint:#018x}); \
                 starting fresh",
                ck.fingerprint
            );
            None
        }
        Ok(None) => None,
        Err(e) => {
            eprintln!(
                "[serve] shard {shard}: unusable checkpoint ({e:#}); \
                 starting fresh"
            );
            None
        }
    }
}

/// The [`CounterSnapshot`] fields in their on-disk order. Kept as ONE
/// list (with [`counter_fields_mut`] mirroring it) so adding a counter
/// without extending the checkpoint layout is a compile error here, not
/// silent data loss.
fn counter_fields(c: &CounterSnapshot) -> [u64; 24] {
    [
        c.oracle_calls,
        c.updates_applied,
        c.collisions,
        c.dropped,
        c.iterations,
        c.snapshot_reads,
        c.payload_nnz,
        c.payload_bytes,
        c.shipped_payload_bytes,
        c.wire_tx_bytes,
        c.wire_rx_bytes,
        c.delay_sum,
        c.delay_max,
        c.workers_joined,
        c.workers_lost,
        c.blocks_requeued,
        c.reconnects,
        c.event_stalls,
        c.checkpoints_written,
        c.restores,
        c.stale_fenced,
        c.gamma_damped_sum,
        c.drops_adaptive,
        c.batch_resizes,
    ]
}

/// Mutable twin of [`counter_fields`] — the decode-side field order.
fn counter_fields_mut(c: &mut CounterSnapshot) -> [&mut u64; 24] {
    [
        &mut c.oracle_calls,
        &mut c.updates_applied,
        &mut c.collisions,
        &mut c.dropped,
        &mut c.iterations,
        &mut c.snapshot_reads,
        &mut c.payload_nnz,
        &mut c.payload_bytes,
        &mut c.shipped_payload_bytes,
        &mut c.wire_tx_bytes,
        &mut c.wire_rx_bytes,
        &mut c.delay_sum,
        &mut c.delay_max,
        &mut c.workers_joined,
        &mut c.workers_lost,
        &mut c.blocks_requeued,
        &mut c.reconnects,
        &mut c.event_stalls,
        &mut c.checkpoints_written,
        &mut c.restores,
        &mut c.stale_fenced,
        &mut c.gamma_damped_sum,
        &mut c.drops_adaptive,
        &mut c.batch_resizes,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> ShardPlan {
        ShardPlan::single("127.0.0.1:7000".to_string(), 8, 16)
    }

    fn pairs() -> Vec<(String, String)> {
        vec![
            ("gfl.d".into(), "4".into()),
            ("run.tau".into(), "2".into()),
        ]
    }

    fn sample_checkpoint() -> Checkpoint {
        let counters = CounterSnapshot {
            updates_applied: 37,
            wire_rx_bytes: 4096,
            delay_max: 5,
            stale_fenced: 2,
            ..Default::default()
        };
        Checkpoint {
            fingerprint: fingerprint(&pairs(), &plan()),
            shard: 0,
            generation: 3,
            k: 37,
            gap_estimate: 0.125,
            master: vec![0.0, 1.5, 0.0, 0.0, -2.25, 0.5, 0.0, 3.0],
            samples: vec![
                Sample {
                    iter: 16,
                    oracle_calls: 16,
                    elapsed_s: 0.5,
                    objective: 1.25,
                    gap: 0.5,
                },
                Sample {
                    iter: 32,
                    oracle_calls: 32,
                    elapsed_s: 1.0,
                    objective: 0.75,
                    gap: 0.25,
                },
            ],
            counters,
            server_state: vec![1, 2, 3, 4, 5],
        }
    }

    #[test]
    fn roundtrips_bit_exactly() {
        let ck = sample_checkpoint();
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        assert_eq!(back.fingerprint, ck.fingerprint);
        assert_eq!(back.shard, ck.shard);
        assert_eq!(back.generation, ck.generation);
        assert_eq!(back.k, ck.k);
        assert_eq!(back.gap_estimate.to_bits(), ck.gap_estimate.to_bits());
        assert_eq!(
            back.master.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            ck.master.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(back.samples.len(), ck.samples.len());
        for (b, s) in back.samples.iter().zip(&ck.samples) {
            assert_eq!(b.iter, s.iter);
            assert_eq!(b.oracle_calls, s.oracle_calls);
            assert_eq!(b.elapsed_s.to_bits(), s.elapsed_s.to_bits());
            assert_eq!(b.objective.to_bits(), s.objective.to_bits());
            assert_eq!(b.gap.to_bits(), s.gap.to_bits());
        }
        assert_eq!(back.counters, ck.counters);
        assert_eq!(back.server_state, ck.server_state);
    }

    #[test]
    fn fingerprint_separates_runs_and_is_stable() {
        let f = fingerprint(&pairs(), &plan());
        assert_eq!(f, fingerprint(&pairs(), &plan()), "deterministic");
        let mut other = pairs();
        other[0].1 = "5".into();
        assert_ne!(f, fingerprint(&other, &plan()), "config change");
        let moved =
            ShardPlan::single("127.0.0.1:7001".to_string(), 8, 16);
        assert_ne!(f, fingerprint(&pairs(), &moved), "plan change");
        // Concatenation ambiguity across the key/value boundary must not
        // collide (the separator's job).
        let a = vec![("ab".to_string(), "c".to_string())];
        let b = vec![("a".to_string(), "bc".to_string())];
        assert_ne!(fingerprint(&a, &plan()), fingerprint(&b, &plan()));
        // Operational knobs must NOT perturb the fingerprint: a restart
        // that adds --restore, extends the wall-clock budget, or drops
        // the chaos op is still "the same run" and must accept its own
        // checkpoints.
        let mut restarted = pairs();
        restarted.push(("run.restore".into(), "true".into()));
        restarted.push(("run.max_secs".into(), "8".into()));
        restarted.push(("run.chaos".into(), "crash:50".into()));
        restarted.push(("run.checkpoint_every".into(), "20".into()));
        assert_eq!(
            f,
            fingerprint(&restarted, &plan()),
            "operational knobs excluded"
        );
    }

    /// PR 8-style hostility sweep: every truncation prefix and every
    /// single-byte flip of a valid image must decode to a clean error —
    /// zero panics, zero false accepts.
    #[test]
    fn corrupt_images_fail_cleanly_never_panic() {
        let image = sample_checkpoint().encode();
        for len in 0..image.len() {
            assert!(
                Checkpoint::decode(&image[..len]).is_err(),
                "truncation to {len} bytes must not decode"
            );
        }
        for pos in 0..image.len() {
            let mut bad = image.clone();
            bad[pos] ^= 0x40;
            // A flip can never be silently accepted: the CRC covers the
            // body, and a flip inside the CRC trailer mismatches the
            // intact body.
            assert!(
                Checkpoint::decode(&bad).is_err(),
                "byte flip at {pos} must not decode"
            );
        }
    }

    #[test]
    fn crc_trailer_rejects_recomputed_garbage() {
        // Flip a body byte AND fix the CRC up: structural validation
        // still owns the failure (bad magic here), proving the decode
        // does not rely on the CRC alone.
        let mut bad = sample_checkpoint().encode();
        bad[0] ^= 0xff; // magic
        let n = bad.len();
        let crc = crc32(&bad[..n - 4]).to_le_bytes();
        bad[n - 4..].copy_from_slice(&crc);
        let err = Checkpoint::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        // Extra bytes between the server state and where the CRC is
        // expected: recompute a valid CRC over the padded body so only
        // the trailing-bytes check can reject it.
        let mut padded = sample_checkpoint().encode();
        let n = padded.len();
        padded.truncate(n - 4);
        padded.extend_from_slice(&[0u8; 3]);
        let crc = crc32(&padded).to_le_bytes();
        padded.extend_from_slice(&crc);
        let err = Checkpoint::decode(&padded).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn atomic_write_then_load_roundtrips() {
        let dir = std::env::temp_dir().join(format!(
            "apfw-ckpt-test-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        let ck = sample_checkpoint();
        ck.write_atomic(&dir).unwrap();
        // No temp file left behind.
        assert!(!shard_path(&dir, 0).with_extension("ckpt.tmp").exists());
        let back = Checkpoint::load(&dir, 0).unwrap().unwrap();
        assert_eq!(back.k, ck.k);
        assert_eq!(back.generation, ck.generation);
        // A second write overwrites in place (same path, still atomic).
        let mut ck2 = ck.clone();
        ck2.k = 99;
        ck2.generation = 4;
        ck2.write_atomic(&dir).unwrap();
        let back = Checkpoint::load(&dir, 0).unwrap().unwrap();
        assert_eq!((back.k, back.generation), (99, 4));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_for_restore_falls_back_fresh_on_every_failure() {
        let dir = std::env::temp_dir().join(format!(
            "apfw-ckpt-restore-test-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        let ck = sample_checkpoint();
        let fp = ck.fingerprint;

        // Missing dir / missing file: fresh start.
        assert!(load_for_restore(&dir, 0, fp).is_none());

        // Valid file, matching fingerprint: restored.
        ck.write_atomic(&dir).unwrap();
        let got = load_for_restore(&dir, 0, fp).expect("restores");
        assert_eq!(got.k, ck.k);

        // Fingerprint mismatch (a different run): fresh start.
        assert!(load_for_restore(&dir, 0, fp ^ 1).is_none());

        // Wrong shard id in the file: fresh start for shard 1 (no file)
        // and, with the file renamed into shard 1's slot, the embedded
        // shard check rejects it.
        assert!(load_for_restore(&dir, 1, fp).is_none());
        fs::rename(shard_path(&dir, 0), shard_path(&dir, 1)).unwrap();
        assert!(load_for_restore(&dir, 1, fp).is_none());
        fs::rename(shard_path(&dir, 1), shard_path(&dir, 0)).unwrap();

        // Corrupt file on disk: fresh start.
        let path = shard_path(&dir, 0);
        let mut raw = fs::read(&path).unwrap();
        let mid = raw.len() / 2;
        raw[mid] ^= 0x10;
        fs::write(&path, &raw).unwrap();
        assert!(load_for_restore(&dir, 0, fp).is_none());

        // Truncated file on disk: fresh start.
        fs::write(&path, &raw[..raw.len() / 3]).unwrap();
        assert!(load_for_restore(&dir, 0, fp).is_none());
        fs::remove_dir_all(&dir).unwrap();
    }
}
