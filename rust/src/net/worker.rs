//! The `worker` role: connect to a serve-role host, pull parameter
//! snapshots, and stream batched oracle payloads back over the wire.
//!
//! A worker is stateless beyond its parameter copy: the handshake
//! ([`super::wire::Hello`]) carries the problem name, the flattened config
//! (so the worker rebuilds the identical [`ProblemInstance`] — data
//! generation is deterministic in the seed), the fan-out batch, and the
//! payload-representation knob. The solve loop then strictly alternates:
//! request a snapshot (full on first contact, dirty-range delta after),
//! solve `batch` distinct blocks against it with the same
//! [`pick_blocks`]/[`oracle_into`] machinery as the in-process engines,
//! and ship one multi-block `Update` frame — sparse payloads stay sparse
//! from the LMO to the server's assembler.
//!
//! Worker `id` samples blocks from rng stream [`rng_stream_for`]`(id)`
//! (`2 + id`): stream 2 is the sequential delayed engine's stream
//! ([`crate::solver::delayed`] draws from the same helper), so a
//! one-worker loopback solve replays the in-process delayed engine
//! draw-for-draw — the bit-identity pinned in
//! `rust/tests/net_transport.rs`. Ids are server-issued, so a session that
//! replaces a broken one gets a fresh id and therefore a fresh stream.
//!
//! Sharded sessions (protocol v3): when the handshake's
//! [`ShardPlan`](super::ShardPlan) names more than one shard, the worker
//! dials every other shard from the plan, handshakes each, and runs one
//! solve loop over the whole fleet of connections — snapshot pulls fan
//! out to every shard under a per-shard version vector (each shard
//! answers deltas over its own parameter span, spliced into the worker's
//! locally initialized copy), blocks are still sampled globally from the
//! one worker rng stream, and each solved payload is routed to the shard
//! owning its block. A round sends an Update to *every* shard — empty
//! for shards that own none of the round's blocks — so the strict
//! request/response alternation each serve loop relies on is preserved
//! per connection.
//!
//! Elastic-fleet behavior (protocol v2): every session announces itself
//! with a `Join` frame right after the handshake, [`run_resilient`]
//! reconnects with jittered exponential backoff when a session breaks
//! mid-run, heartbeats keep a liveness-enabled server from mistaking a
//! slow oracle for a dead worker, and the `run.chaos` knob (shipped to the
//! worker inside the handshake config) wraps the transport in the
//! fault-injecting [`ChaosStream`].
//!
//! Crash recovery (protocol v5): the handshake carries the serve shard's
//! `generation` — bumped on every restart — and every `Update` this
//! worker ships is stamped with the owning shard's generation, so a
//! restored apply core can fence frames computed against pre-crash state.
//! A restored server also announces `resume_draws`, the number of block
//! draws the pre-crash session consumed; the worker fast-forwards its
//! sampling stream by discarding that many [`pick_blocks`] calls, which
//! is what makes a crash+restore loopback solve bit-identical to an
//! uninterrupted one. Reconnects retry through refused connections until
//! the window elapses (a restarting server needs time to rebind), and
//! with liveness enabled the worker heartbeats *while blocked* on a
//! snapshot answer, so the slow full-snapshot fallback right after a
//! restore cannot get it liveness-reaped.
//!
//! [`oracle_into`]: crate::problems::Problem::oracle_into
//! [`pick_blocks`]: crate::coordinator::pick_blocks

use super::chaos::{chaos_rng_stream, ChaosStream};
use super::wire::{self, Hello, Msg, SnapshotBody};
use super::{payload_mode_from_tag, rng_stream_for, NetOptions};
use crate::coordinator::pick_blocks;
use crate::sim::adapt::{next_batch, AdaptSpec, BatchPolicy};
use crate::problems::{BlockOracle, OracleScratch, Problem};
use crate::run::ProblemInstance;
use crate::util::config::Config;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, bail, ensure, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// What a worker did over its lifetime (summed across every session when
/// [`run_resilient`] reconnects).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerSummary {
    /// Worker id assigned by the server (the latest session's, when the
    /// worker reconnected under a fresh id).
    pub worker_id: u32,
    /// Snapshot-pull/solve/update rounds completed.
    pub rounds: u64,
    /// Oracle subproblems solved.
    pub oracle_calls: u64,
    /// Frame bytes sent (join + updates + snapshot requests + heartbeats).
    pub tx_bytes: u64,
    /// Frame bytes received (handshake + snapshots + shutdown).
    pub rx_bytes: u64,
    /// Sessions that successfully resumed after a broken connection.
    pub reconnects: u64,
    /// Whether the last connection ended with an explicit `Shutdown` frame
    /// or a clean EOF. `false` means a transport failure ended the loop —
    /// possibly mid-solve, though a server teardown can also surface as a
    /// reset when frames race the close, so this is a diagnostic signal,
    /// not an error.
    pub clean: bool,
}

impl WorkerSummary {
    /// Fold one session's totals into the running lifetime summary.
    fn absorb(&mut self, session: &WorkerSummary) {
        self.worker_id = session.worker_id;
        self.rounds += session.rounds;
        self.oracle_calls += session.oracle_calls;
        self.tx_bytes += session.tx_bytes;
        self.rx_bytes += session.rx_bytes;
        self.clean = session.clean;
    }
}

/// Connect to `addr`, complete the handshake, and run the oracle loop
/// until the server shuts the solve down. A connection that ends after the
/// handshake (shutdown frame, EOF, or reset — the server closes sockets
/// on stop) is a clean exit; failures *before* the handshake and protocol
/// violations are errors. Single-session: a mid-run disconnect ends the
/// worker (see [`run_resilient`] for the reconnecting variant).
pub fn run(addr: &str) -> Result<WorkerSummary> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    run_on(stream, false)
}

/// [`run`], but retry the initial connect until `timeout` elapses — so a
/// worker can be started before (or seconds after) its server.
pub fn run_with_retry(addr: &str, timeout: Duration) -> Result<WorkerSummary> {
    let mut jitter = backoff_rng();
    let stream = connect_until(addr, timeout, &mut jitter)?;
    run_on(stream, false)
}

/// The elastic-fleet worker: like [`run_with_retry`], but when an
/// established session breaks mid-run (socket failure, injected chaos
/// disconnect, server-side liveness kill), reconnect with jittered
/// exponential backoff — announcing the new session as resumed — and keep
/// solving under the fresh server-issued id. Returns the summed summary
/// once a session ends cleanly, or, after at least one session, once the
/// server stops answering for the whole reconnect window. Refused
/// connections are retried until that window elapses — a crashed serve
/// process needs time to restart and rebind before it can answer, and
/// concluding "run over" on the first refusal would strand exactly the
/// recovery the checkpoint/restore path exists for. `connect_timeout`
/// bounds both the initial connect and each reconnect window.
pub fn run_resilient(
    addr: &str,
    connect_timeout: Duration,
) -> Result<WorkerSummary> {
    let mut jitter = backoff_rng();
    let mut total = WorkerSummary::default();
    let mut resumed = false;
    loop {
        let stream =
            match connect_until(addr, connect_timeout, &mut jitter) {
                Ok(s) => s,
                // Initial connects must fail loudly; reconnects report
                // what the completed sessions achieved.
                Err(e) if !resumed => return Err(e),
                Err(_) => return Ok(total),
            };
        match run_on(stream, resumed) {
            Ok(session) => {
                total.absorb(&session);
                if resumed {
                    total.reconnects += 1;
                }
                if session.clean {
                    return Ok(total);
                }
            }
            // A handshake error on the very first session is a real
            // misconfiguration; on a resume it is almost always the
            // reconnect racing the server's teardown.
            Err(e) if !resumed => return Err(e),
            Err(_) => return Ok(total),
        }
        resumed = true;
    }
}

/// Seed the backoff-jitter rng from wall-clock nanos: restarted workers
/// must NOT share a schedule (a thundering herd of identically-timed
/// reconnects is exactly what jitter exists to break up). Block sampling
/// stays fully deterministic — this rng never touches it.
fn backoff_rng() -> Pcg64 {
    let seed = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0x5eed);
    Pcg64::new(seed, 1)
}

/// Connect to `addr`, retrying with jittered exponential backoff (nominal
/// 100 ms doubling to a 2 s ceiling, each step scaled by 0.5–1.5x) until
/// `window` elapses. Every failure kind retries, *including* an explicit
/// connection refusal: "nothing is listening" is indistinguishable from
/// "the serve process crashed and is restarting with `--restore`", and
/// treating it as final used to end resumed runs that were seconds away
/// from recovering. The window is the only arbiter of giving up.
fn connect_until(
    addr: &str,
    window: Duration,
    jitter: &mut Pcg64,
) -> Result<TcpStream> {
    let deadline = Instant::now() + window;
    let mut backoff = Duration::from_millis(100);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!(
                        "could not connect to {addr} within {window:?}: {e}"
                    ));
                }
                let step = backoff.mul_f64(0.5 + jitter.uniform());
                let left = deadline.saturating_duration_since(Instant::now());
                std::thread::sleep(step.min(left));
                backoff = (backoff * 2).min(Duration::from_secs(2));
            }
        }
    }
}

/// Transports whose blocking reads can be bounded by a deadline, so a
/// worker blocked on a slow snapshot answer can surface periodically and
/// send heartbeats instead of sitting invisible until the server's
/// liveness reaper books it dead. `None` restores fully blocking reads.
trait PullTimeout {
    fn set_read_timeout(&self, timeout: Option<Duration>)
        -> std::io::Result<()>;
}

impl PullTimeout for TcpStream {
    fn set_read_timeout(
        &self,
        timeout: Option<Duration>,
    ) -> std::io::Result<()> {
        TcpStream::set_read_timeout(self, timeout)
    }
}

impl<S: PullTimeout> PullTimeout for ChaosStream<S> {
    fn set_read_timeout(
        &self,
        timeout: Option<Duration>,
    ) -> std::io::Result<()> {
        self.get_ref().set_read_timeout(timeout)
    }
}

/// A [`Read`] adapter that turns read timeouts into heartbeat ticks.
/// Each time `streams[target]` reports `WouldBlock`/`TimedOut` (the read
/// timeout armed by [`read_frame_patient`]), every stream in the fleet
/// whose outbound side has been quiet for a full heartbeat period gets a
/// `Heartbeat` frame, then the read retries. Timeouts never surface to
/// the frame decoder, so a header or body fill resumes exactly where it
/// left off — a half-read frame survives any number of ticks (a timed-out
/// socket read consumes nothing; partial data arrives as a short read,
/// which the decoder already handles).
struct HeartbeatOnStall<'a, S> {
    streams: &'a mut [S],
    target: usize,
    period: Duration,
    last_tx: &'a mut [Instant],
    tx_bytes: &'a mut u64,
    ebuf: &'a mut Vec<u8>,
}

impl<S: Read + Write> Read for HeartbeatOnStall<'_, S> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            match self.streams[self.target].read(buf) {
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    for (s, stream) in self.streams.iter_mut().enumerate() {
                        if self.last_tx[s].elapsed() < self.period {
                            continue;
                        }
                        match wire::write_frame(
                            stream,
                            &Msg::Heartbeat,
                            self.ebuf,
                        ) {
                            Ok(nb) => {
                                *self.tx_bytes += nb as u64;
                                self.last_tx[s] = Instant::now();
                            }
                            // Only a failure on the stream being read
                            // kills the pull; a sibling's broken pipe
                            // surfaces on its own next send.
                            Err(err) if s == self.target => {
                                return Err(std::io::Error::new(
                                    std::io::ErrorKind::BrokenPipe,
                                    err.to_string(),
                                ));
                            }
                            Err(_) => {}
                        }
                    }
                }
                other => return other,
            }
        }
    }
}

/// Read one frame from `streams[target]`, heartbeating while blocked:
/// with liveness enabled, the target's read timeout is armed at the
/// heartbeat period for the duration of the read, so a server that takes
/// long to answer a pull — e.g. assembling the full-snapshot fallback
/// right after a crash restore — cannot get this worker liveness-reaped
/// while it patiently waits. Streams other than the target tick too: a
/// sharded pull collects answers in shard order, and a slow early shard
/// must not starve the later shards of heartbeats. Without a heartbeat
/// period this is exactly [`wire::read_frame`].
fn read_frame_patient<S: Read + Write + PullTimeout>(
    streams: &mut [S],
    target: usize,
    heartbeat: Option<Duration>,
    last_tx: &mut [Instant],
    tx_bytes: &mut u64,
    ebuf: &mut Vec<u8>,
) -> Result<Option<(Msg, usize)>> {
    let Some(period) = heartbeat else {
        return wire::read_frame(&mut streams[target]);
    };
    // A transport that cannot arm a timeout falls back to the plain
    // blocking read: the deadline is a liveness optimization, never a
    // correctness requirement.
    let tick = period.max(Duration::from_millis(1));
    if streams[target].set_read_timeout(Some(tick)).is_err() {
        return wire::read_frame(&mut streams[target]);
    }
    let got = wire::read_frame(&mut HeartbeatOnStall {
        streams,
        target,
        period,
        last_tx,
        tx_bytes,
        ebuf,
    });
    streams[target].set_read_timeout(None).ok();
    got
}

/// Run the worker protocol over an established connection. `resumed` is
/// forwarded in the session's `Join` announcement (the server's
/// `reconnects` telemetry).
fn run_on(mut stream: TcpStream, resumed: bool) -> Result<WorkerSummary> {
    let mut rx_bytes = 0u64;
    let (hello, nbytes) = match wire::read_frame(&mut stream)? {
        Some((Msg::Hello(h), n)) => (h, n),
        Some((other, _)) => {
            bail!("expected a Hello handshake, got {other:?}")
        }
        None => bail!("server closed the connection before the handshake"),
    };
    rx_bytes += nbytes as u64;

    // Announce the session (v2): the first worker->server frame, before
    // any snapshot traffic, so the server can count joins/resumes without
    // touching its event ordering.
    let mut ebuf = Vec::new();
    let tx_bytes =
        wire::write_frame(&mut stream, &Msg::Join { resumed }, &mut ebuf)?
            as u64;

    // Rebuild the problem instance from the shipped config; data
    // generation is seeded, so this is the server's instance bit-for-bit.
    let mut cfg = Config::new();
    for (key, value) in &hello.config {
        cfg.set(key, value);
    }
    let instance = ProblemInstance::from_config(&hello.problem, &cfg)?;
    ensure!(
        instance.num_blocks() == hello.n_blocks as usize,
        "configuration drift: server expects {} blocks, this worker built \
         {} — are the binaries/config in sync?",
        hello.n_blocks,
        instance.num_blocks()
    );
    // The fleet knobs ride in the same shipped config: heartbeat cadence
    // from the server's liveness window, fault injection from `run.chaos`.
    let opts = NetOptions::from_config(&cfg)?;
    // Adaptive fan-out bounds (`run.adapt.batch = auto:MIN:MAX`): the
    // session cap also respects the fleet-wide `batch * workers <= n`
    // invariant the fixed-batch Runner enforces statically. `None` keeps
    // the historical fixed-batch solve loop exactly. NetOptions already
    // rejected the sharded and checkpointed combinations, so the sharded
    // loop below never sees an adaptive batch.
    let batch_bounds = match AdaptSpec::from_config(&cfg)?.batch {
        BatchPolicy::Off => None,
        BatchPolicy::Auto { min, max } => {
            let workers = cfg.get_usize("run.workers", 2).max(1);
            let cap = max.min((instance.num_blocks() / workers).max(1));
            Some((min.min(cap).max(1), cap))
        }
    };
    if hello.plan.len() > 1 {
        // Sharded parameter plane: dial the sibling shards named in the
        // plan and run the fan-out solve loop over all of them.
        return run_sharded(
            &instance, hello, stream, &opts, resumed, rx_bytes, tx_bytes,
        );
    }
    let heartbeat = opts.heartbeat_period();
    // `run.wire` rides in the same shipped config, so the worker's
    // update pushes use exactly the encoding the serve side configured.
    let wmode = opts.wire;
    if opts.chaos.is_noop() {
        // No chaos: the raw stream, bit-identical to the plain transport.
        dispatch(
            &instance,
            &hello,
            stream,
            rx_bytes,
            tx_bytes,
            heartbeat,
            wmode,
            batch_bounds,
        )
    } else {
        let rng = Pcg64::new(hello.seed, chaos_rng_stream(hello.worker_id));
        let stream = ChaosStream::new(stream, opts.chaos, rng);
        dispatch(
            &instance,
            &hello,
            stream,
            rx_bytes,
            tx_bytes,
            heartbeat,
            wmode,
            batch_bounds,
        )
    }
}

/// Establish the full sharded session: keep the already-handshaken
/// `primary` connection, dial every other shard named in the plan,
/// handshake and announce each, then hand the whole fleet of connections
/// to the sharded solve loop (chaos-wrapped per stream when enabled).
fn run_sharded(
    instance: &ProblemInstance,
    hello: Hello,
    primary: TcpStream,
    opts: &NetOptions,
    resumed: bool,
    rx_bytes: u64,
    tx_bytes: u64,
) -> Result<WorkerSummary> {
    let plan = hello.plan.clone();
    let s_count = plan.len();
    let primary_shard = hello.shard as usize;
    let mut hellos: Vec<Option<Hello>> = (0..s_count).map(|_| None).collect();
    let mut raw: Vec<Option<TcpStream>> =
        (0..s_count).map(|_| None).collect();
    let mut rx = rx_bytes;
    let mut tx = tx_bytes;
    let mut jitter = backoff_rng();
    let mut ebuf = Vec::new();
    for s in 0..s_count {
        if s == primary_shard {
            continue;
        }
        // The sibling shards bind before any shard accepts, so they are
        // reachable by the time the primary handshake completed; the
        // retry window only absorbs scheduling skew between processes.
        let mut stream = connect_until(
            &plan.get(s).addr,
            opts.accept_timeout,
            &mut jitter,
        )?;
        let (h, nb) = match wire::read_frame(&mut stream)? {
            Some((Msg::Hello(h), nb)) => (h, nb),
            Some((other, _)) => {
                bail!("shard {s}: expected a Hello handshake, got {other:?}")
            }
            None => {
                bail!("shard {s} closed the connection before the handshake")
            }
        };
        rx += nb as u64;
        ensure!(
            h.shard as usize == s && h.plan == plan,
            "shard plan mismatch: the peer at {} answered as shard {} of a \
             different plan — are the serve processes in sync?",
            plan.get(s).addr,
            h.shard
        );
        tx += wire::write_frame(&mut stream, &Msg::Join { resumed }, &mut ebuf)?
            as u64;
        hellos[s] = Some(h);
        raw[s] = Some(stream);
    }
    hellos[primary_shard] = Some(hello);
    raw[primary_shard] = Some(primary);
    let hellos: Vec<Hello> = hellos
        .into_iter()
        .map(|h| h.expect("every shard handshaken"))
        .collect();
    let streams: Vec<TcpStream> = raw
        .into_iter()
        .map(|s| s.expect("every shard connected"))
        .collect();
    let heartbeat = opts.heartbeat_period();
    if opts.chaos.is_noop() {
        dispatch_sharded(
            instance,
            &hellos,
            primary_shard,
            streams,
            rx,
            tx,
            heartbeat,
            opts.wire,
        )
    } else {
        // One chaos rng per connection: the per-shard worker ids may
        // collide across shards, so fold the shard index into the stream
        // selector to keep the fault schedules independent.
        let wrapped: Vec<ChaosStream<TcpStream>> = streams
            .into_iter()
            .enumerate()
            .map(|(s, st)| {
                let rng = Pcg64::new(
                    hellos[s].seed,
                    chaos_rng_stream(hellos[s].worker_id)
                        + ((s as u64) << 32),
                );
                ChaosStream::new(st, opts.chaos.clone(), rng)
            })
            .collect();
        dispatch_sharded(
            instance,
            &hellos,
            primary_shard,
            wrapped,
            rx,
            tx,
            heartbeat,
            opts.wire,
        )
    }
}

/// Monomorphize [`sharded_solve_loop`] over the instance's problem type.
#[allow(clippy::too_many_arguments)]
fn dispatch_sharded<S: Read + Write + PullTimeout>(
    instance: &ProblemInstance,
    hellos: &[Hello],
    primary: usize,
    streams: Vec<S>,
    rx_bytes: u64,
    tx_bytes: u64,
    heartbeat: Option<Duration>,
    wmode: wire::WireMode,
) -> Result<WorkerSummary> {
    match instance {
        ProblemInstance::Gfl(p) => sharded_solve_loop(
            p, hellos, primary, streams, rx_bytes, tx_bytes, heartbeat,
            wmode,
        ),
        ProblemInstance::Qp(p) => sharded_solve_loop(
            p, hellos, primary, streams, rx_bytes, tx_bytes, heartbeat,
            wmode,
        ),
        ProblemInstance::Chain(p) => sharded_solve_loop(
            p, hellos, primary, streams, rx_bytes, tx_bytes, heartbeat,
            wmode,
        ),
        ProblemInstance::Multiclass(p) => sharded_solve_loop(
            p, hellos, primary, streams, rx_bytes, tx_bytes, heartbeat,
            wmode,
        ),
    }
}

/// The sharded oracle loop: fan snapshot pulls to every shard, splice
/// their span deltas into one locally held parameter, solve a globally
/// sampled batch, and route each payload to the shard owning its block.
/// Every round ends with one Update per shard — empty for shards owning
/// none of the round's blocks — preserving the per-connection strict
/// alternation. `k_read` is per shard: the version of *that shard's*
/// span the oracles were computed against, so each shard's staleness rule
/// judges exactly the state it owns.
#[allow(clippy::too_many_arguments)]
fn sharded_solve_loop<P: Problem, S: Read + Write + PullTimeout>(
    problem: &P,
    hellos: &[Hello],
    primary: usize,
    mut streams: Vec<S>,
    mut rx_bytes: u64,
    tx_bytes: u64,
    heartbeat: Option<Duration>,
    wmode: wire::WireMode,
) -> Result<WorkerSummary> {
    let n = problem.num_blocks();
    let plan = &hellos[primary].plan;
    let s_count = plan.len();
    // Defense in depth: the serve side built this plan, but the worker
    // splices snapshot runs straight into its parameter, so re-check the
    // tiling against the locally rebuilt instance before trusting it.
    plan.validate(n, problem.param_dim())?;
    let batch = (hellos[primary].batch as usize).clamp(1, n);
    let mode =
        payload_mode_from_tag(hellos[primary].payload_mode).ok_or_else(
            || anyhow!("unknown payload mode tag {}", hellos[primary].payload_mode),
        )?;
    let pkind = mode.resolve(problem.preferred_payload());
    // ONE sampling stream for the whole sharded session, derived from the
    // primary shard's issued id — block sampling is global; the plan only
    // decides where each solved payload is shipped.
    let mut rng =
        Pcg64::new(hellos[primary].seed, rng_stream_for(hellos[primary].worker_id));
    // Local deterministic init instead of a Full pull: each shard only
    // ever answers delta runs over its own span, and splicing those into
    // the initial iterate reconstructs the assembled parameter.
    let mut param: Vec<f32> = problem.init_param();
    // Per-shard version vector: shard s's spans are at version have[s].
    // Reset per session (see the single-shard loop): after a restore no
    // pre-crash version may be trusted, so every shard's first answer is
    // judged against the never-matching `u64::MAX`.
    let mut have: Vec<u64> = vec![u64::MAX; s_count];
    let mut blocks: Vec<usize> = Vec::new();
    // Crash recovery (v5): fast-forward the one global sampling stream by
    // the primary shard's announced draw count (see the single-shard loop
    // for why whole `pick_blocks` calls are discarded, never rng words).
    for _ in 0..hellos[primary].resume_draws {
        pick_blocks(&mut rng, n, batch, &mut blocks);
    }
    let mut oscratch = OracleScratch::<P>::default();
    let mut slots: Vec<BlockOracle> =
        (0..batch).map(|_| BlockOracle::empty_with(pkind)).collect();
    let mut groups: Vec<Vec<BlockOracle>> =
        (0..s_count).map(|_| Vec::with_capacity(batch)).collect();
    let mut ebuf: Vec<u8> = Vec::new();
    let mut summary = WorkerSummary {
        worker_id: hellos[primary].worker_id,
        tx_bytes,
        ..Default::default()
    };
    let mut last_tx: Vec<Instant> =
        (0..s_count).map(|_| Instant::now()).collect();
    let mut clean = false;
    let mut done = false;

    'session: while !done {
        // ---- pull: fan the snapshot request to every shard ----
        let mut asked = vec![false; s_count];
        for s in 0..s_count {
            match wire::write_frame(
                &mut streams[s],
                &Msg::SnapshotRequest {
                    have_version: have[s],
                },
                &mut ebuf,
            ) {
                Ok(nb) => {
                    summary.tx_bytes += nb as u64;
                    last_tx[s] = Instant::now();
                    asked[s] = true;
                }
                // A serve loop closes sockets on stop; a failed send
                // after the handshake is the shutdown path, not an
                // error. Shards already asked still get their answers
                // read below so the conversation ends in protocol.
                Err(_) => {
                    done = true;
                    break;
                }
            }
        }
        // ---- collect one answer per asked shard ----
        for s in 0..s_count {
            if !asked[s] {
                continue;
            }
            let (version, body) = match read_frame_patient(
                &mut streams,
                s,
                heartbeat,
                &mut last_tx,
                &mut summary.tx_bytes,
                &mut ebuf,
            ) {
                Ok(Some((Msg::Snapshot { version, body }, nb))) => {
                    rx_bytes += nb as u64;
                    (version, body)
                }
                Ok(Some((Msg::Shutdown, nb))) => {
                    rx_bytes += nb as u64;
                    clean = true;
                    done = true;
                    continue;
                }
                Ok(Some((other, _))) => {
                    bail!("shard {s}: expected Snapshot or Shutdown, got {other:?}")
                }
                Ok(None) => {
                    clean = true;
                    done = true;
                    continue;
                }
                Err(_) => {
                    done = true;
                    continue;
                }
            };
            match body {
                SnapshotBody::Full(values) => {
                    ensure!(
                        values.len() == problem.param_dim(),
                        "shard {s}: snapshot dimension {} != parameter \
                         dimension {}",
                        values.len(),
                        problem.param_dim()
                    );
                    param = values;
                }
                SnapshotBody::Delta(runs) => {
                    for (off, values) in &runs {
                        let lo = *off as usize;
                        let hi = lo + values.len();
                        ensure!(
                            hi <= param.len(),
                            "shard {s}: delta run {lo}..{hi} out of bounds \
                             (dim {})",
                            param.len()
                        );
                        param[lo..hi].copy_from_slice(values);
                    }
                }
            }
            have[s] = version;
        }
        if done {
            break 'session;
        }

        // ---- solve ----
        pick_blocks(&mut rng, n, batch, &mut blocks);
        'solve: for (slot, &block) in slots.iter_mut().zip(blocks.iter()) {
            if let Some(period) = heartbeat {
                for s in 0..s_count {
                    if last_tx[s].elapsed() >= period {
                        match wire::write_frame(
                            &mut streams[s],
                            &Msg::Heartbeat,
                            &mut ebuf,
                        ) {
                            Ok(nb) => {
                                summary.tx_bytes += nb as u64;
                                last_tx[s] = Instant::now();
                            }
                            Err(_) => {
                                done = true;
                                break 'solve;
                            }
                        }
                    }
                }
            }
            problem.oracle_into(&param, block, &mut oscratch, slot);
            summary.oracle_calls += 1;
        }
        if done {
            // The round was abandoned mid-solve: skip the push (the
            // serve side requeues anything outstanding) and wind down.
            break 'session;
        }

        // ---- push: route each payload to its block's owner ----
        for (slot, &block) in slots.drain(..).zip(blocks.iter()) {
            groups[plan.owner_of(block)].push(slot);
        }
        for s in 0..s_count {
            let msg = Msg::Update {
                k_read: have[s],
                worker: hellos[s].worker_id,
                // Each shard restores (and fences) independently, so the
                // stamp is the *owning* shard's handshake generation.
                generation: hellos[s].generation,
                oracles: std::mem::take(&mut groups[s]),
            };
            // The update push is the worker's one mode-aware write:
            // under f16/q8 the sparse payload values ship quantized.
            let sent = wire::write_frame_mode(
                &mut streams[s],
                &msg,
                &mut ebuf,
                wmode,
            );
            // Recover the payload containers whether or not the send
            // landed — their buffers are reused every round.
            if let Msg::Update { oracles, .. } = msg {
                slots.extend(oracles);
            }
            match sent {
                Ok(nb) => {
                    summary.tx_bytes += nb as u64;
                    last_tx[s] = Instant::now();
                }
                Err(_) => done = true,
            }
        }
        summary.rounds += 1;
    }

    // Wind-down. On a clean end (some shard said Shutdown or closed at a
    // frame boundary) the plane is stopping: finish the conversation with
    // every other shard — each owes at most one snapshot answer and sends
    // its own Shutdown within its next loop turn — so no serve loop sees
    // a mid-protocol EOF and books a phantom worker death. On a transport
    // failure the session really is lost: drop everything at once and let
    // the resilient wrapper decide whether to rejoin.
    if clean {
        for stream in streams.iter_mut() {
            loop {
                match wire::read_frame(stream) {
                    Ok(Some((Msg::Shutdown, nb))) => {
                        rx_bytes += nb as u64;
                        break;
                    }
                    Ok(Some((_, nb))) => rx_bytes += nb as u64,
                    Ok(None) | Err(_) => break,
                }
            }
        }
    }
    summary.clean = clean;
    summary.rx_bytes = rx_bytes;
    Ok(summary)
}

/// Monomorphize [`solve_loop`] over the instance's problem type.
#[allow(clippy::too_many_arguments)]
fn dispatch<S: Read + Write + PullTimeout>(
    instance: &ProblemInstance,
    hello: &Hello,
    stream: S,
    rx_bytes: u64,
    tx_bytes: u64,
    heartbeat: Option<Duration>,
    wmode: wire::WireMode,
    bounds: Option<(usize, usize)>,
) -> Result<WorkerSummary> {
    match instance {
        ProblemInstance::Gfl(p) => solve_loop(
            p, hello, stream, rx_bytes, tx_bytes, heartbeat, wmode, bounds,
        ),
        ProblemInstance::Qp(p) => solve_loop(
            p, hello, stream, rx_bytes, tx_bytes, heartbeat, wmode, bounds,
        ),
        ProblemInstance::Chain(p) => solve_loop(
            p, hello, stream, rx_bytes, tx_bytes, heartbeat, wmode, bounds,
        ),
        ProblemInstance::Multiclass(p) => solve_loop(
            p, hello, stream, rx_bytes, tx_bytes, heartbeat, wmode, bounds,
        ),
    }
}

/// The generic oracle loop: pull, solve `batch` blocks, push, repeat.
/// Generic over the transport so the chaos wrapper slots in untouched.
/// With `heartbeat` set (server liveness enabled), a `Heartbeat` frame is
/// sent whenever that long passes without other outbound traffic — checked
/// between oracle calls, so even a long multi-block solve stays visibly
/// alive.
///
/// With `bounds` set (`run.adapt.batch = auto`), the fan-out batch
/// self-tunes between rounds from observed snapshot-pull latency: cheap
/// pulls grow tau_w toward the cap (amortizing the pull over more
/// oracles), contended pulls shrink it toward the floor ([`next_batch`]).
/// The resize happens before the round's `pick_blocks`, so the Update
/// payload length reflects it — which is how the serve side counts
/// `batch_resizes` without any wire change. `None` keeps the historical
/// fixed-batch loop untouched.
#[allow(clippy::too_many_arguments)]
fn solve_loop<P: Problem, S: Read + Write + PullTimeout>(
    problem: &P,
    hello: &Hello,
    mut stream: S,
    mut rx_bytes: u64,
    tx_bytes: u64,
    heartbeat: Option<Duration>,
    wmode: wire::WireMode,
    bounds: Option<(usize, usize)>,
) -> Result<WorkerSummary> {
    let n = problem.num_blocks();
    let mut batch = (hello.batch as usize).clamp(1, n);
    if let Some((floor, cap)) = bounds {
        batch = batch.clamp(floor, cap);
    }
    // Adaptive-batch controller state: smoothed and best-seen pull cost.
    let mut pull_ema = 0.0f64;
    let mut best_pull = 0.0f64;
    let mode = payload_mode_from_tag(hello.payload_mode).ok_or_else(|| {
        anyhow!("unknown payload mode tag {}", hello.payload_mode)
    })?;
    let pkind = mode.resolve(problem.preferred_payload());
    let mut rng = Pcg64::new(hello.seed, rng_stream_for(hello.worker_id));
    let mut param: Vec<f32> = Vec::new();
    // Nothing pulled yet -> the first request takes the full-snapshot
    // fallback. The reset is deliberately per session: a worker that
    // reconnects after a server crash+restore must not trust any version
    // it pulled from the pre-crash generation, and `u64::MAX` never
    // matches a real version, so the first pull always re-bootstraps.
    let mut have: u64 = u64::MAX;
    let mut blocks: Vec<usize> = Vec::new();
    // Crash recovery (v5): a restored server tells the session how many
    // block draws the pre-crash run consumed, and the worker fast-forwards
    // by discarding exactly that many `pick_blocks` calls — never raw rng
    // words, because rejection sampling consumes a variable number per
    // draw. In the lockstep loopback regime this resumes the draw sequence
    // precisely where the checkpoint left it, which is what makes a
    // crash+restore solve bit-identical to an uninterrupted one.
    for _ in 0..hello.resume_draws {
        pick_blocks(&mut rng, n, batch, &mut blocks);
    }
    let mut oscratch = OracleScratch::<P>::default();
    let mut slots: Vec<BlockOracle> =
        (0..batch).map(|_| BlockOracle::empty_with(pkind)).collect();
    let mut ebuf: Vec<u8> = Vec::new();
    let mut summary = WorkerSummary {
        worker_id: hello.worker_id,
        tx_bytes,
        ..Default::default()
    };
    let mut last_tx = Instant::now();

    'session: loop {
        // ---- pull ----
        let pull_started = Instant::now();
        match wire::write_frame(
            &mut stream,
            &Msg::SnapshotRequest { have_version: have },
            &mut ebuf,
        ) {
            Ok(nb) => {
                summary.tx_bytes += nb as u64;
                last_tx = Instant::now();
            }
            // The server closes sockets on stop; a failed send after the
            // handshake is the shutdown path, not an error.
            Err(_) => break,
        }
        let (version, body) = match read_frame_patient(
            std::slice::from_mut(&mut stream),
            0,
            heartbeat,
            std::slice::from_mut(&mut last_tx),
            &mut summary.tx_bytes,
            &mut ebuf,
        ) {
            Ok(Some((Msg::Snapshot { version, body }, nb))) => {
                rx_bytes += nb as u64;
                (version, body)
            }
            Ok(Some((Msg::Shutdown, nb))) => {
                rx_bytes += nb as u64;
                summary.clean = true;
                break;
            }
            Ok(Some((other, _))) => {
                bail!("expected Snapshot or Shutdown, got {other:?}")
            }
            Ok(None) => {
                summary.clean = true;
                break;
            }
            Err(_) => break,
        };
        match body {
            SnapshotBody::Full(values) => {
                ensure!(
                    values.len() == problem.param_dim(),
                    "snapshot dimension {} != parameter dimension {}",
                    values.len(),
                    problem.param_dim()
                );
                param = values;
            }
            SnapshotBody::Delta(runs) => {
                ensure!(
                    !param.is_empty(),
                    "delta snapshot before any full snapshot"
                );
                for (off, values) in &runs {
                    let lo = *off as usize;
                    let hi = lo + values.len();
                    ensure!(
                        hi <= param.len(),
                        "delta run {lo}..{hi} out of bounds (dim {})",
                        param.len()
                    );
                    param[lo..hi].copy_from_slice(values);
                }
            }
        }
        have = version;

        // ---- retune the fan-out from the observed pull cost ----
        if let Some((floor, cap)) = bounds {
            let secs = pull_started.elapsed().as_secs_f64();
            pull_ema = if pull_ema > 0.0 {
                0.8 * pull_ema + 0.2 * secs
            } else {
                secs
            };
            if best_pull <= 0.0 || secs < best_pull {
                best_pull = secs;
            }
            let next = next_batch(batch, floor, cap, pull_ema, best_pull);
            if next != batch {
                batch = next;
                slots.resize_with(batch, || BlockOracle::empty_with(pkind));
            }
        }

        // ---- solve ----
        pick_blocks(&mut rng, n, batch, &mut blocks);
        for (slot, &block) in slots.iter_mut().zip(blocks.iter()) {
            if let Some(period) = heartbeat {
                if last_tx.elapsed() >= period {
                    match wire::write_frame(
                        &mut stream,
                        &Msg::Heartbeat,
                        &mut ebuf,
                    ) {
                        Ok(nb) => {
                            summary.tx_bytes += nb as u64;
                            last_tx = Instant::now();
                        }
                        Err(_) => break 'session,
                    }
                }
            }
            problem.oracle_into(&param, block, &mut oscratch, slot);
            summary.oracle_calls += 1;
        }

        // ---- push ----
        // Encoding borrows the slots, so their buffers are reused across
        // rounds — the wire path adds no per-oracle allocation on the
        // worker side.
        let msg = Msg::Update {
            k_read: version,
            worker: hello.worker_id,
            // Stamped from the handshake: a frame from a session that
            // predates a crash restore carries the old generation and is
            // fenced (counted, dropped) by the restored apply core.
            generation: hello.generation,
            oracles: std::mem::take(&mut slots),
        };
        // The update push is the worker's one mode-aware write: under
        // f16/q8 the sparse payload values ship quantized.
        let sent =
            wire::write_frame_mode(&mut stream, &msg, &mut ebuf, wmode);
        if let Msg::Update { oracles, .. } = msg {
            slots = oracles;
        }
        match sent {
            Ok(nb) => {
                summary.tx_bytes += nb as u64;
                last_tx = Instant::now();
            }
            Err(_) => break,
        }
        summary.rounds += 1;
    }
    summary.rx_bytes = rx_bytes;
    Ok(summary)
}
