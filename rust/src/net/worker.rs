//! The `worker` role: connect to a serve-role host, pull parameter
//! snapshots, and stream batched oracle payloads back over the wire.
//!
//! A worker is stateless beyond its parameter copy: the handshake
//! ([`super::wire::Hello`]) carries the problem name, the flattened config
//! (so the worker rebuilds the identical [`ProblemInstance`] — data
//! generation is deterministic in the seed), the fan-out batch, and the
//! payload-representation knob. The solve loop then strictly alternates:
//! request a snapshot (full on first contact, dirty-range delta after),
//! solve `batch` distinct blocks against it with the same
//! [`pick_blocks`]/[`oracle_into`] machinery as the in-process engines,
//! and ship one multi-block `Update` frame — sparse payloads stay sparse
//! from the LMO to the server's assembler.
//!
//! Worker `id` samples blocks from rng stream `2 + id`: stream 2 is the
//! sequential delayed engine's stream ([`crate::solver::delayed`] draws
//! from `Pcg64::new(seed, 2)`), so a one-worker loopback solve replays the
//! in-process delayed engine draw-for-draw — the bit-identity pinned in
//! `rust/tests/net_transport.rs`.
//!
//! [`oracle_into`]: crate::problems::Problem::oracle_into
//! [`pick_blocks`]: crate::coordinator::pick_blocks

use super::wire::{self, Hello, Msg, SnapshotBody};
use super::{payload_mode_from_tag, worker_rng_stream};
use crate::coordinator::pick_blocks;
use crate::problems::{BlockOracle, OracleScratch, Problem};
use crate::run::ProblemInstance;
use crate::util::config::Config;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, bail, ensure, Result};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// What a worker did over one connection's lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerSummary {
    /// Worker id assigned by the server.
    pub worker_id: u32,
    /// Snapshot-pull/solve/update rounds completed.
    pub rounds: u64,
    /// Oracle subproblems solved.
    pub oracle_calls: u64,
    /// Frame bytes sent (updates + snapshot requests).
    pub tx_bytes: u64,
    /// Frame bytes received (handshake + snapshots + shutdown).
    pub rx_bytes: u64,
    /// Whether the connection ended with an explicit `Shutdown` frame or
    /// a clean EOF. `false` means a transport failure ended the loop —
    /// possibly mid-solve, though a server teardown can also surface as a
    /// reset when frames race the close, so this is a diagnostic signal,
    /// not an error.
    pub clean: bool,
}

/// Connect to `addr`, complete the handshake, and run the oracle loop
/// until the server shuts the solve down. A connection that ends after the
/// handshake (shutdown frame, EOF, or reset — the server closes sockets
/// on stop) is a clean exit; failures *before* the handshake and protocol
/// violations are errors.
pub fn run(addr: &str) -> Result<WorkerSummary> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    run_on(stream)
}

/// [`run`], but retry the initial connect until `timeout` elapses — the
/// CLI uses this so `apbcfw worker` can be started before (or seconds
/// after) its server.
pub fn run_with_retry(addr: &str, timeout: Duration) -> Result<WorkerSummary> {
    let deadline = Instant::now() + timeout;
    let stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!(
                        "could not connect to {addr} within {timeout:?}: {e}"
                    ));
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    };
    stream.set_nodelay(true).ok();
    run_on(stream)
}

/// Run the worker protocol over an established connection.
fn run_on(mut stream: TcpStream) -> Result<WorkerSummary> {
    let mut rx_bytes = 0u64;
    let (hello, nbytes) = match wire::read_frame(&mut stream)? {
        Some((Msg::Hello(h), n)) => (h, n),
        Some((other, _)) => {
            bail!("expected a Hello handshake, got {other:?}")
        }
        None => bail!("server closed the connection before the handshake"),
    };
    rx_bytes += nbytes as u64;

    // Rebuild the problem instance from the shipped config; data
    // generation is seeded, so this is the server's instance bit-for-bit.
    let mut cfg = Config::new();
    for (key, value) in &hello.config {
        cfg.set(key, value);
    }
    let instance = ProblemInstance::from_config(&hello.problem, &cfg)?;
    ensure!(
        instance.num_blocks() == hello.n_blocks as usize,
        "configuration drift: server expects {} blocks, this worker built \
         {} — are the binaries/config in sync?",
        hello.n_blocks,
        instance.num_blocks()
    );
    match &instance {
        ProblemInstance::Gfl(p) => solve_loop(p, &hello, stream, rx_bytes),
        ProblemInstance::Qp(p) => solve_loop(p, &hello, stream, rx_bytes),
        ProblemInstance::Chain(p) => solve_loop(p, &hello, stream, rx_bytes),
        ProblemInstance::Multiclass(p) => {
            solve_loop(p, &hello, stream, rx_bytes)
        }
    }
}

/// The generic oracle loop: pull, solve `batch` blocks, push, repeat.
fn solve_loop<P: Problem>(
    problem: &P,
    hello: &Hello,
    mut stream: TcpStream,
    mut rx_bytes: u64,
) -> Result<WorkerSummary> {
    let n = problem.num_blocks();
    let batch = (hello.batch as usize).clamp(1, n);
    let mode = payload_mode_from_tag(hello.payload_mode).ok_or_else(|| {
        anyhow!("unknown payload mode tag {}", hello.payload_mode)
    })?;
    let pkind = mode.resolve(problem.preferred_payload());
    let mut rng =
        Pcg64::new(hello.seed, worker_rng_stream(hello.worker_id));
    let mut param: Vec<f32> = Vec::new();
    let mut have: u64 = u64::MAX; // nothing yet -> full snapshot
    let mut blocks: Vec<usize> = Vec::new();
    let mut oscratch = OracleScratch::<P>::default();
    let mut slots: Vec<BlockOracle> =
        (0..batch).map(|_| BlockOracle::empty_with(pkind)).collect();
    let mut ebuf: Vec<u8> = Vec::new();
    let mut summary = WorkerSummary {
        worker_id: hello.worker_id,
        ..Default::default()
    };

    loop {
        // ---- pull ----
        match wire::write_frame(
            &mut stream,
            &Msg::SnapshotRequest { have_version: have },
            &mut ebuf,
        ) {
            Ok(nb) => summary.tx_bytes += nb as u64,
            // The server closes sockets on stop; a failed send after the
            // handshake is the shutdown path, not an error.
            Err(_) => break,
        }
        let (version, body) = match wire::read_frame(&mut stream) {
            Ok(Some((Msg::Snapshot { version, body }, nb))) => {
                rx_bytes += nb as u64;
                (version, body)
            }
            Ok(Some((Msg::Shutdown, nb))) => {
                rx_bytes += nb as u64;
                summary.clean = true;
                break;
            }
            Ok(Some((other, _))) => {
                bail!("expected Snapshot or Shutdown, got {other:?}")
            }
            Ok(None) => {
                summary.clean = true;
                break;
            }
            Err(_) => break,
        };
        match body {
            SnapshotBody::Full(values) => {
                ensure!(
                    values.len() == problem.param_dim(),
                    "snapshot dimension {} != parameter dimension {}",
                    values.len(),
                    problem.param_dim()
                );
                param = values;
            }
            SnapshotBody::Delta(runs) => {
                ensure!(
                    !param.is_empty(),
                    "delta snapshot before any full snapshot"
                );
                for (off, values) in &runs {
                    let lo = *off as usize;
                    let hi = lo + values.len();
                    ensure!(
                        hi <= param.len(),
                        "delta run {lo}..{hi} out of bounds (dim {})",
                        param.len()
                    );
                    param[lo..hi].copy_from_slice(values);
                }
            }
        }
        have = version;

        // ---- solve ----
        pick_blocks(&mut rng, n, batch, &mut blocks);
        for (slot, &block) in slots.iter_mut().zip(blocks.iter()) {
            problem.oracle_into(&param, block, &mut oscratch, slot);
            summary.oracle_calls += 1;
        }

        // ---- push ----
        // Encoding borrows the slots, so their buffers are reused across
        // rounds — the wire path adds no per-oracle allocation on the
        // worker side.
        let msg = Msg::Update {
            k_read: version,
            worker: hello.worker_id,
            oracles: std::mem::take(&mut slots),
        };
        let sent = wire::write_frame(&mut stream, &msg, &mut ebuf);
        if let Msg::Update { oracles, .. } = msg {
            slots = oracles;
        }
        match sent {
            Ok(nb) => summary.tx_bytes += nb as u64,
            Err(_) => break,
        }
        summary.rounds += 1;
    }
    summary.rx_bytes = rx_bytes;
    Ok(summary)
}
