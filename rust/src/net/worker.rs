//! The `worker` role: connect to a serve-role host, pull parameter
//! snapshots, and stream batched oracle payloads back over the wire.
//!
//! A worker is stateless beyond its parameter copy: the handshake
//! ([`super::wire::Hello`]) carries the problem name, the flattened config
//! (so the worker rebuilds the identical [`ProblemInstance`] — data
//! generation is deterministic in the seed), the fan-out batch, and the
//! payload-representation knob. The solve loop then strictly alternates:
//! request a snapshot (full on first contact, dirty-range delta after),
//! solve `batch` distinct blocks against it with the same
//! [`pick_blocks`]/[`oracle_into`] machinery as the in-process engines,
//! and ship one multi-block `Update` frame — sparse payloads stay sparse
//! from the LMO to the server's assembler.
//!
//! Worker `id` samples blocks from rng stream `2 + id`: stream 2 is the
//! sequential delayed engine's stream ([`crate::solver::delayed`] draws
//! from `Pcg64::new(seed, 2)`), so a one-worker loopback solve replays the
//! in-process delayed engine draw-for-draw — the bit-identity pinned in
//! `rust/tests/net_transport.rs`. Ids are server-issued, so a session that
//! replaces a broken one gets a fresh id and therefore a fresh stream.
//!
//! Elastic-fleet behavior (protocol v2): every session announces itself
//! with a `Join` frame right after the handshake, [`run_resilient`]
//! reconnects with jittered exponential backoff when a session breaks
//! mid-run, heartbeats keep a liveness-enabled server from mistaking a
//! slow oracle for a dead worker, and the `run.chaos` knob (shipped to the
//! worker inside the handshake config) wraps the transport in the
//! fault-injecting [`ChaosStream`].
//!
//! [`oracle_into`]: crate::problems::Problem::oracle_into
//! [`pick_blocks`]: crate::coordinator::pick_blocks

use super::chaos::{chaos_rng_stream, ChaosStream};
use super::wire::{self, Hello, Msg, SnapshotBody};
use super::{payload_mode_from_tag, worker_rng_stream, NetOptions};
use crate::coordinator::pick_blocks;
use crate::problems::{BlockOracle, OracleScratch, Problem};
use crate::run::ProblemInstance;
use crate::util::config::Config;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, bail, ensure, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// What a worker did over its lifetime (summed across every session when
/// [`run_resilient`] reconnects).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerSummary {
    /// Worker id assigned by the server (the latest session's, when the
    /// worker reconnected under a fresh id).
    pub worker_id: u32,
    /// Snapshot-pull/solve/update rounds completed.
    pub rounds: u64,
    /// Oracle subproblems solved.
    pub oracle_calls: u64,
    /// Frame bytes sent (join + updates + snapshot requests + heartbeats).
    pub tx_bytes: u64,
    /// Frame bytes received (handshake + snapshots + shutdown).
    pub rx_bytes: u64,
    /// Sessions that successfully resumed after a broken connection.
    pub reconnects: u64,
    /// Whether the last connection ended with an explicit `Shutdown` frame
    /// or a clean EOF. `false` means a transport failure ended the loop —
    /// possibly mid-solve, though a server teardown can also surface as a
    /// reset when frames race the close, so this is a diagnostic signal,
    /// not an error.
    pub clean: bool,
}

impl WorkerSummary {
    /// Fold one session's totals into the running lifetime summary.
    fn absorb(&mut self, session: &WorkerSummary) {
        self.worker_id = session.worker_id;
        self.rounds += session.rounds;
        self.oracle_calls += session.oracle_calls;
        self.tx_bytes += session.tx_bytes;
        self.rx_bytes += session.rx_bytes;
        self.clean = session.clean;
    }
}

/// Connect to `addr`, complete the handshake, and run the oracle loop
/// until the server shuts the solve down. A connection that ends after the
/// handshake (shutdown frame, EOF, or reset — the server closes sockets
/// on stop) is a clean exit; failures *before* the handshake and protocol
/// violations are errors. Single-session: a mid-run disconnect ends the
/// worker (see [`run_resilient`] for the reconnecting variant).
pub fn run(addr: &str) -> Result<WorkerSummary> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    run_on(stream, false)
}

/// [`run`], but retry the initial connect until `timeout` elapses — so a
/// worker can be started before (or seconds after) its server.
pub fn run_with_retry(addr: &str, timeout: Duration) -> Result<WorkerSummary> {
    let mut jitter = backoff_rng();
    let stream = connect_until(addr, timeout, false, &mut jitter)?;
    run_on(stream, false)
}

/// The elastic-fleet worker: like [`run_with_retry`], but when an
/// established session breaks mid-run (socket failure, injected chaos
/// disconnect, server-side liveness kill), reconnect with jittered
/// exponential backoff — announcing the new session as resumed — and keep
/// solving under the fresh server-issued id. Returns the summed summary
/// once a session ends cleanly, or, after at least one session, once the
/// server stops answering (a vanished listener usually just means the run
/// is over). `connect_timeout` bounds both the initial connect and each
/// reconnect window.
pub fn run_resilient(
    addr: &str,
    connect_timeout: Duration,
) -> Result<WorkerSummary> {
    let mut jitter = backoff_rng();
    let mut total = WorkerSummary::default();
    let mut resumed = false;
    loop {
        let stream =
            match connect_until(addr, connect_timeout, resumed, &mut jitter) {
                Ok(s) => s,
                // Initial connects must fail loudly; reconnects report
                // what the completed sessions achieved.
                Err(e) if !resumed => return Err(e),
                Err(_) => return Ok(total),
            };
        match run_on(stream, resumed) {
            Ok(session) => {
                total.absorb(&session);
                if resumed {
                    total.reconnects += 1;
                }
                if session.clean {
                    return Ok(total);
                }
            }
            // A handshake error on the very first session is a real
            // misconfiguration; on a resume it is almost always the
            // reconnect racing the server's teardown.
            Err(e) if !resumed => return Err(e),
            Err(_) => return Ok(total),
        }
        resumed = true;
    }
}

/// Seed the backoff-jitter rng from wall-clock nanos: restarted workers
/// must NOT share a schedule (a thundering herd of identically-timed
/// reconnects is exactly what jitter exists to break up). Block sampling
/// stays fully deterministic — this rng never touches it.
fn backoff_rng() -> Pcg64 {
    let seed = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0x5eed);
    Pcg64::new(seed, 1)
}

/// Connect to `addr`, retrying with jittered exponential backoff (nominal
/// 100 ms doubling to a 2 s ceiling, each step scaled by 0.5–1.5x) until
/// `window` elapses. With `refused_is_final`, an explicit connection
/// refusal returns immediately: nothing is listening, so for a resuming
/// session the run is over.
fn connect_until(
    addr: &str,
    window: Duration,
    refused_is_final: bool,
    jitter: &mut Pcg64,
) -> Result<TcpStream> {
    let deadline = Instant::now() + window;
    let mut backoff = Duration::from_millis(100);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => {
                if refused_is_final
                    && e.kind() == std::io::ErrorKind::ConnectionRefused
                {
                    return Err(anyhow!("{addr} refused the connection: {e}"));
                }
                if Instant::now() >= deadline {
                    return Err(anyhow!(
                        "could not connect to {addr} within {window:?}: {e}"
                    ));
                }
                let step = backoff.mul_f64(0.5 + jitter.uniform());
                let left = deadline.saturating_duration_since(Instant::now());
                std::thread::sleep(step.min(left));
                backoff = (backoff * 2).min(Duration::from_secs(2));
            }
        }
    }
}

/// Run the worker protocol over an established connection. `resumed` is
/// forwarded in the session's `Join` announcement (the server's
/// `reconnects` telemetry).
fn run_on(mut stream: TcpStream, resumed: bool) -> Result<WorkerSummary> {
    let mut rx_bytes = 0u64;
    let (hello, nbytes) = match wire::read_frame(&mut stream)? {
        Some((Msg::Hello(h), n)) => (h, n),
        Some((other, _)) => {
            bail!("expected a Hello handshake, got {other:?}")
        }
        None => bail!("server closed the connection before the handshake"),
    };
    rx_bytes += nbytes as u64;

    // Announce the session (v2): the first worker->server frame, before
    // any snapshot traffic, so the server can count joins/resumes without
    // touching its event ordering.
    let mut ebuf = Vec::new();
    let tx_bytes =
        wire::write_frame(&mut stream, &Msg::Join { resumed }, &mut ebuf)?
            as u64;

    // Rebuild the problem instance from the shipped config; data
    // generation is seeded, so this is the server's instance bit-for-bit.
    let mut cfg = Config::new();
    for (key, value) in &hello.config {
        cfg.set(key, value);
    }
    let instance = ProblemInstance::from_config(&hello.problem, &cfg)?;
    ensure!(
        instance.num_blocks() == hello.n_blocks as usize,
        "configuration drift: server expects {} blocks, this worker built \
         {} — are the binaries/config in sync?",
        hello.n_blocks,
        instance.num_blocks()
    );
    // The fleet knobs ride in the same shipped config: heartbeat cadence
    // from the server's liveness window, fault injection from `run.chaos`.
    let opts = NetOptions::from_config(&cfg)?;
    let heartbeat = opts.heartbeat_period();
    if opts.chaos.is_noop() {
        // No chaos: the raw stream, bit-identical to the plain transport.
        dispatch(&instance, &hello, stream, rx_bytes, tx_bytes, heartbeat)
    } else {
        let rng = Pcg64::new(hello.seed, chaos_rng_stream(hello.worker_id));
        let stream = ChaosStream::new(stream, opts.chaos, rng);
        dispatch(&instance, &hello, stream, rx_bytes, tx_bytes, heartbeat)
    }
}

/// Monomorphize [`solve_loop`] over the instance's problem type.
fn dispatch<S: Read + Write>(
    instance: &ProblemInstance,
    hello: &Hello,
    stream: S,
    rx_bytes: u64,
    tx_bytes: u64,
    heartbeat: Option<Duration>,
) -> Result<WorkerSummary> {
    match instance {
        ProblemInstance::Gfl(p) => {
            solve_loop(p, hello, stream, rx_bytes, tx_bytes, heartbeat)
        }
        ProblemInstance::Qp(p) => {
            solve_loop(p, hello, stream, rx_bytes, tx_bytes, heartbeat)
        }
        ProblemInstance::Chain(p) => {
            solve_loop(p, hello, stream, rx_bytes, tx_bytes, heartbeat)
        }
        ProblemInstance::Multiclass(p) => {
            solve_loop(p, hello, stream, rx_bytes, tx_bytes, heartbeat)
        }
    }
}

/// The generic oracle loop: pull, solve `batch` blocks, push, repeat.
/// Generic over the transport so the chaos wrapper slots in untouched.
/// With `heartbeat` set (server liveness enabled), a `Heartbeat` frame is
/// sent whenever that long passes without other outbound traffic — checked
/// between oracle calls, so even a long multi-block solve stays visibly
/// alive.
fn solve_loop<P: Problem, S: Read + Write>(
    problem: &P,
    hello: &Hello,
    mut stream: S,
    mut rx_bytes: u64,
    tx_bytes: u64,
    heartbeat: Option<Duration>,
) -> Result<WorkerSummary> {
    let n = problem.num_blocks();
    let batch = (hello.batch as usize).clamp(1, n);
    let mode = payload_mode_from_tag(hello.payload_mode).ok_or_else(|| {
        anyhow!("unknown payload mode tag {}", hello.payload_mode)
    })?;
    let pkind = mode.resolve(problem.preferred_payload());
    let mut rng =
        Pcg64::new(hello.seed, worker_rng_stream(hello.worker_id));
    let mut param: Vec<f32> = Vec::new();
    let mut have: u64 = u64::MAX; // nothing yet -> full snapshot
    let mut blocks: Vec<usize> = Vec::new();
    let mut oscratch = OracleScratch::<P>::default();
    let mut slots: Vec<BlockOracle> =
        (0..batch).map(|_| BlockOracle::empty_with(pkind)).collect();
    let mut ebuf: Vec<u8> = Vec::new();
    let mut summary = WorkerSummary {
        worker_id: hello.worker_id,
        tx_bytes,
        ..Default::default()
    };
    let mut last_tx = Instant::now();

    'session: loop {
        // ---- pull ----
        match wire::write_frame(
            &mut stream,
            &Msg::SnapshotRequest { have_version: have },
            &mut ebuf,
        ) {
            Ok(nb) => {
                summary.tx_bytes += nb as u64;
                last_tx = Instant::now();
            }
            // The server closes sockets on stop; a failed send after the
            // handshake is the shutdown path, not an error.
            Err(_) => break,
        }
        let (version, body) = match wire::read_frame(&mut stream) {
            Ok(Some((Msg::Snapshot { version, body }, nb))) => {
                rx_bytes += nb as u64;
                (version, body)
            }
            Ok(Some((Msg::Shutdown, nb))) => {
                rx_bytes += nb as u64;
                summary.clean = true;
                break;
            }
            Ok(Some((other, _))) => {
                bail!("expected Snapshot or Shutdown, got {other:?}")
            }
            Ok(None) => {
                summary.clean = true;
                break;
            }
            Err(_) => break,
        };
        match body {
            SnapshotBody::Full(values) => {
                ensure!(
                    values.len() == problem.param_dim(),
                    "snapshot dimension {} != parameter dimension {}",
                    values.len(),
                    problem.param_dim()
                );
                param = values;
            }
            SnapshotBody::Delta(runs) => {
                ensure!(
                    !param.is_empty(),
                    "delta snapshot before any full snapshot"
                );
                for (off, values) in &runs {
                    let lo = *off as usize;
                    let hi = lo + values.len();
                    ensure!(
                        hi <= param.len(),
                        "delta run {lo}..{hi} out of bounds (dim {})",
                        param.len()
                    );
                    param[lo..hi].copy_from_slice(values);
                }
            }
        }
        have = version;

        // ---- solve ----
        pick_blocks(&mut rng, n, batch, &mut blocks);
        for (slot, &block) in slots.iter_mut().zip(blocks.iter()) {
            if let Some(period) = heartbeat {
                if last_tx.elapsed() >= period {
                    match wire::write_frame(
                        &mut stream,
                        &Msg::Heartbeat,
                        &mut ebuf,
                    ) {
                        Ok(nb) => {
                            summary.tx_bytes += nb as u64;
                            last_tx = Instant::now();
                        }
                        Err(_) => break 'session,
                    }
                }
            }
            problem.oracle_into(&param, block, &mut oscratch, slot);
            summary.oracle_calls += 1;
        }

        // ---- push ----
        // Encoding borrows the slots, so their buffers are reused across
        // rounds — the wire path adds no per-oracle allocation on the
        // worker side.
        let msg = Msg::Update {
            k_read: version,
            worker: hello.worker_id,
            oracles: std::mem::take(&mut slots),
        };
        let sent = wire::write_frame(&mut stream, &msg, &mut ebuf);
        if let Msg::Update { oracles, .. } = msg {
            slots = oracles;
        }
        match sent {
            Ok(nb) => {
                summary.tx_bytes += nb as u64;
                last_tx = Instant::now();
            }
            Err(_) => break,
        }
        summary.rounds += 1;
    }
    summary.rx_bytes = rx_bytes;
    Ok(summary)
}
