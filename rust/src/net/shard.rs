//! Block→shard partitioning for the sharded parameter plane
//! (`run.shards > 1`).
//!
//! The paper's block-separable structure (Eq. 2) gives every coordinate
//! block — and, for problems that declare block-local writes via
//! [`Problem::touched_ranges`] — a disjoint slice of the parameter
//! vector. A [`ShardPlan`] carves both into `S` contiguous spans so that
//! each block (and each parameter index) has exactly one owning shard:
//! workers route every Update frame to its block's owner and fan
//! snapshot pulls out to all shards, merging the per-span answers into
//! one local view under a per-shard version vector. No cross-shard
//! coordination is needed on the apply path; the relaxed per-shard block
//! sampling order is covered by the flexible block-iterative analysis of
//! Braun–Pokutta–Woodstock (arXiv:2409.06931), and tolerance of the
//! partial/stale fan-out views by Zhuo et al. (arXiv:1910.07703).
//!
//! The plan is computed once by the serve rendezvous
//! ([`ShardPlan::build`]) and shipped to every worker inside the Hello
//! handshake (WIRE.md §4.1, protocol v3), so workers never guess the
//! partition: the routing table is part of the session contract.

use crate::coordinator::RunResult;
use crate::problems::Problem;
use crate::util::metrics::Sample;
use anyhow::{bail, ensure, Result};
use std::ops::Range;

/// One shard's slice of the plane: where to reach it and which
/// half-open block/parameter spans it owns. Spans are `u32` on the wire
/// (WIRE.md §4.1); the accessors below widen to `usize`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardInfo {
    /// `host:port` the shard's listener is reachable at.
    pub addr: String,
    /// First owned block id.
    pub block_start: u32,
    /// One past the last owned block id.
    pub block_end: u32,
    /// First owned parameter index.
    pub param_start: u32,
    /// One past the last owned parameter index.
    pub param_end: u32,
}

/// The contiguous block→shard partition carried in the Hello handshake.
///
/// Invariants (checked by [`ShardPlan::validate`]): shard block spans
/// are nonempty, ascending, and tile `0..n_blocks` exactly; parameter
/// spans are ascending and tile `0..param_dim` exactly. Together they
/// make [`ShardPlan::owner_of`] total and unambiguous.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Shards in ascending block order (index = shard id).
    pub shards: Vec<ShardInfo>,
}

impl ShardPlan {
    /// The trivial one-shard plan: everything owned by `addr`. This is
    /// what `run.shards = 1` serves in its Hello — v2 peers never see a
    /// plan, v3 single-shard peers see this degenerate one.
    pub fn single(addr: String, n_blocks: usize, param_dim: usize) -> Self {
        ShardPlan {
            shards: vec![ShardInfo {
                addr,
                block_start: 0,
                block_end: n_blocks as u32,
                param_start: 0,
                param_end: param_dim as u32,
            }],
        }
    }

    /// Number of shards S.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// True for a plan with no shards (only a decoded-from-hostile-bytes
    /// state; every constructor produces at least one shard).
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// True for the degenerate one-shard plan (the unsharded wire
    /// session).
    pub fn is_single(&self) -> bool {
        self.shards.len() <= 1
    }

    /// Shard `s`'s entry.
    pub fn get(&self, s: usize) -> &ShardInfo {
        &self.shards[s]
    }

    /// Shard `s`'s owned block span.
    pub fn block_range(&self, s: usize) -> Range<usize> {
        let sh = &self.shards[s];
        sh.block_start as usize..sh.block_end as usize
    }

    /// Shard `s`'s owned parameter span.
    pub fn param_span(&self, s: usize) -> Range<usize> {
        let sh = &self.shards[s];
        sh.param_start as usize..sh.param_end as usize
    }

    /// The shard owning `block`. Block spans tile `0..n` ascending, so
    /// the owner is the first shard whose span ends past `block`.
    pub fn owner_of(&self, block: usize) -> usize {
        self.shards
            .partition_point(|sh| (sh.block_end as usize) <= block)
    }

    /// Check the tiling invariants against the session's problem shape.
    /// Workers run this on the decoded Hello plan before trusting it as
    /// a routing table.
    pub fn validate(&self, n_blocks: usize, param_dim: usize) -> Result<()> {
        ensure!(!self.shards.is_empty(), "shard plan has no shards");
        let (mut b, mut p) = (0u32, 0u32);
        for (s, sh) in self.shards.iter().enumerate() {
            ensure!(
                sh.block_start == b && sh.block_end > sh.block_start,
                "shard {s} block span {}..{} breaks the contiguous \
                 tiling at block {b}",
                sh.block_start,
                sh.block_end,
            );
            ensure!(
                sh.param_start == p && sh.param_end >= sh.param_start,
                "shard {s} param span {}..{} breaks the contiguous \
                 tiling at index {p}",
                sh.param_start,
                sh.param_end,
            );
            b = sh.block_end;
            p = sh.param_end;
        }
        ensure!(
            b as usize == n_blocks,
            "shard plan covers {b} blocks, problem has {n_blocks}"
        );
        ensure!(
            p as usize == param_dim,
            "shard plan covers {p} parameter indices, problem has \
             {param_dim}"
        );
        Ok(())
    }

    /// Build the plan for `problem` across `addrs.len()` shards: blocks
    /// are split evenly (`s*n/S`), and each shard's parameter span is
    /// grown from the union of its blocks' declared
    /// [`Problem::touched_ranges`], then padded outward so the spans
    /// tile `0..param_dim` exactly (snapshot fan-out needs every index
    /// owned). Fails for problems with dense (`None`) touched ranges —
    /// a whole-parameter write has no single owner — and for plans
    /// whose block spans would interleave writes across shards.
    pub fn build<P: Problem>(problem: &P, addrs: Vec<String>) -> Result<Self> {
        let s_count = addrs.len();
        let n = problem.num_blocks();
        let dim = problem.param_dim();
        ensure!(s_count >= 1, "a shard plan needs at least one shard");
        ensure!(
            s_count <= n,
            "run.shards = {s_count} exceeds the problem's {n} blocks"
        );
        if s_count == 1 {
            let addr = addrs.into_iter().next().expect("checked nonempty");
            return Ok(ShardPlan::single(addr, n, dim));
        }
        // Per-block write spans, probed once from the initial iterate.
        // `touched_ranges` is a static structural declaration for every
        // registered problem, so the probe point does not matter.
        let init = problem.init_param();
        let mut spans = Vec::with_capacity(n);
        for b in 0..n {
            let o = problem.oracle(&init, b);
            let batch = [o];
            let Some(ranges) = problem.touched_ranges(&batch) else {
                bail!(
                    "problem '{}' applies dense whole-parameter writes \
                     (touched_ranges = None); only problems with \
                     block-local writes can be sharded",
                    problem.name()
                );
            };
            let lo = ranges.iter().map(|r| r.start).min().unwrap_or(0);
            let hi = ranges.iter().map(|r| r.end).max().unwrap_or(0);
            spans.push(lo..hi);
        }
        // Even block partition, then the union of owned block spans.
        let mut shards = Vec::with_capacity(s_count);
        for s in 0..s_count {
            let (bs, be) = (s * n / s_count, (s + 1) * n / s_count);
            let lo = spans[bs..be].iter().map(|r| r.start).min().unwrap();
            let hi = spans[bs..be].iter().map(|r| r.end).max().unwrap();
            shards.push((bs, be, lo, hi));
        }
        for w in shards.windows(2) {
            let ((_, be, _, hi), (bs, _, lo, _)) = (&w[0], &w[1]);
            ensure!(
                hi <= lo,
                "blocks {}.. and ..{} write overlapping parameter \
                 ranges ({hi} > {lo}); this problem's blocks interleave \
                 and cannot be sharded contiguously",
                bs,
                be,
            );
        }
        // Pad spans outward into a tiling of 0..dim: shard 0 absorbs
        // the head, each boundary snaps to the next shard's first
        // write, shard S-1 absorbs the tail.
        let infos = addrs
            .into_iter()
            .enumerate()
            .map(|(s, addr)| ShardInfo {
                addr,
                block_start: shards[s].0 as u32,
                block_end: shards[s].1 as u32,
                param_start: if s == 0 { 0 } else { shards[s].2 as u32 },
                param_end: if s + 1 == s_count {
                    dim as u32
                } else {
                    shards[s + 1].2 as u32
                },
            })
            .collect();
        let plan = ShardPlan { shards: infos };
        plan.validate(n, dim)?;
        Ok(plan)
    }
}

/// Fold the per-shard [`RunResult`]s of one sharded serve into the
/// single result the Report is built from: counters summed
/// (`delay_max` maxed, wall-clock maxed), the final parameter spliced
/// from each hosted shard's owned span, and one exact final sample
/// evaluated on the assembled iterate.
///
/// Fleet counters (`workers_joined`, `reconnects`, …) count per-shard
/// *sessions*: a worker that joins S shards contributes S joins. That
/// is the honest wire-level number — each shard really did run a
/// handshake — and keeps the fold order-free.
///
/// Only hosted shards contribute parameter spans; a `--shard-id`
/// process hosting a strict subset reports the foreign spans at their
/// initial value (its Report is a shard-local view; the cross-process
/// fold lives with whoever collects the per-process Reports).
pub fn aggregate<P: Problem>(
    problem: &P,
    plan: &ShardPlan,
    hosted: &[usize],
    results: Vec<RunResult>,
) -> RunResult {
    assert_eq!(hosted.len(), results.len(), "one result per hosted shard");
    assert!(!results.is_empty(), "aggregate needs at least one shard");
    let mut counters = results[0].counters;
    let mut elapsed_s = results[0].elapsed_s;
    for r in &results[1..] {
        let s = &r.counters;
        counters.oracle_calls += s.oracle_calls;
        counters.updates_applied += s.updates_applied;
        counters.collisions += s.collisions;
        counters.dropped += s.dropped;
        counters.iterations += s.iterations;
        counters.snapshot_reads += s.snapshot_reads;
        counters.payload_nnz += s.payload_nnz;
        counters.payload_bytes += s.payload_bytes;
        counters.shipped_payload_bytes += s.shipped_payload_bytes;
        counters.wire_tx_bytes += s.wire_tx_bytes;
        counters.wire_rx_bytes += s.wire_rx_bytes;
        counters.delay_sum += s.delay_sum;
        counters.delay_max = counters.delay_max.max(s.delay_max);
        counters.workers_joined += s.workers_joined;
        counters.workers_lost += s.workers_lost;
        counters.blocks_requeued += s.blocks_requeued;
        counters.reconnects += s.reconnects;
        counters.event_stalls += s.event_stalls;
        counters.checkpoints_written += s.checkpoints_written;
        counters.restores += s.restores;
        counters.stale_fenced += s.stale_fenced;
        elapsed_s = elapsed_s.max(r.elapsed_s);
    }
    let mut param = problem.init_param();
    for (&s, r) in hosted.iter().zip(&results) {
        let span = plan.param_span(s);
        param[span.clone()].copy_from_slice(&r.raw_param[span]);
    }
    // Sharded serves reject weighted averaging and run ServerState-free
    // problems (build() demands block-local writes), so a fresh state
    // evaluates the assembled iterate exactly.
    let state = problem.init_server();
    let objective = problem.objective(&state, &param);
    let gap = problem.full_gap(&state, &param);
    let mut trace = results
        .first()
        .map(|r| r.trace.clone())
        .unwrap_or_default();
    trace.push(Sample {
        iter: counters.iterations as usize,
        oracle_calls: counters.oracle_calls,
        elapsed_s,
        objective,
        gap,
    });
    let n = problem.num_blocks();
    let passes = counters.updates_applied as f64 / n as f64;
    let secs_per_pass = if passes > 0.0 {
        elapsed_s / passes
    } else {
        f64::INFINITY
    };
    RunResult {
        trace,
        param: param.clone(),
        raw_param: param,
        counters,
        elapsed_s,
        secs_per_pass,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::gfl::Gfl;
    use crate::util::rng::Pcg64;

    fn gfl_instance(d: usize, n: usize) -> Gfl {
        let mut rng = Pcg64::seeded(11);
        let y = rng.gaussian_vec(d * n);
        Gfl::new(d, n, 0.2, y)
    }

    #[test]
    fn single_plan_owns_everything() {
        let plan = ShardPlan::single("127.0.0.1:7878".into(), 9, 36);
        assert!(plan.is_single());
        assert_eq!(plan.block_range(0), 0..9);
        assert_eq!(plan.param_span(0), 0..36);
        assert_eq!(plan.owner_of(0), 0);
        assert_eq!(plan.owner_of(8), 0);
        plan.validate(9, 36).expect("trivial plan validates");
    }

    #[test]
    fn build_tiles_gfl_blocks_and_params() {
        // gfl d=4 n=10 -> m = 9 blocks, param_dim = 36, block b writes
        // 4b..4b+4.
        let p = gfl_instance(4, 10);
        let addrs = vec!["a:1".into(), "b:2".into(), "c:3".into()];
        let plan = ShardPlan::build(&p, addrs).expect("gfl shards");
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.block_range(0), 0..3);
        assert_eq!(plan.block_range(1), 3..6);
        assert_eq!(plan.block_range(2), 6..9);
        assert_eq!(plan.param_span(0), 0..12);
        assert_eq!(plan.param_span(1), 12..24);
        assert_eq!(plan.param_span(2), 24..36);
        plan.validate(9, 36).expect("built plan validates");
        // Ownership is total and matches the block tiling.
        for b in 0..9 {
            assert_eq!(plan.owner_of(b), b / 3, "block {b}");
        }
    }

    #[test]
    fn build_rejects_more_shards_than_blocks() {
        let p = gfl_instance(3, 3); // 2 blocks
        let addrs = vec!["a:1".into(), "b:2".into(), "c:3".into()];
        let err = ShardPlan::build(&p, addrs).unwrap_err().to_string();
        assert!(err.contains("run.shards"), "got: {err}");
    }

    #[test]
    fn validate_rejects_gaps_overlaps_and_short_covers() {
        let mk = |spans: &[(u32, u32, u32, u32)]| ShardPlan {
            shards: spans
                .iter()
                .map(|&(bs, be, ps, pe)| ShardInfo {
                    addr: "x:0".into(),
                    block_start: bs,
                    block_end: be,
                    param_start: ps,
                    param_end: pe,
                })
                .collect(),
        };
        // Gap in the block tiling.
        assert!(mk(&[(0, 2, 0, 8), (3, 4, 8, 16)]).validate(4, 16).is_err());
        // Overlapping param spans.
        assert!(mk(&[(0, 2, 0, 9), (2, 4, 8, 16)]).validate(4, 16).is_err());
        // Covers fewer blocks than the problem has.
        assert!(mk(&[(0, 2, 0, 16)]).validate(4, 16).is_err());
        // Empty plan.
        assert!(mk(&[]).validate(4, 16).is_err());
        // A correct tiling passes.
        mk(&[(0, 2, 0, 8), (2, 4, 8, 16)])
            .validate(4, 16)
            .expect("correct tiling");
    }

    #[test]
    fn aggregate_sums_counters_and_splices_spans() {
        let p = gfl_instance(4, 5); // 4 blocks, dim 16
        let plan = ShardPlan::build(&p, vec!["a:1".into(), "b:2".into()])
            .expect("plan");
        let mut make = |mark: f32, span: std::ops::Range<usize>| {
            let mut raw = p.init_param();
            for v in &mut raw[span] {
                *v = mark;
            }
            let c = crate::util::metrics::CounterSnapshot {
                updates_applied: 3,
                delay_max: if mark > 1.5 { 7 } else { 2 },
                ..Default::default()
            };
            RunResult {
                trace: Default::default(),
                param: raw.clone(),
                raw_param: raw,
                counters: c,
                elapsed_s: mark as f64,
                secs_per_pass: 1.0,
            }
        };
        let r0 = make(1.0, plan.param_span(0));
        let r1 = make(2.0, plan.param_span(1));
        let out = aggregate(&p, &plan, &[0, 1], vec![r0, r1]);
        assert_eq!(out.counters.updates_applied, 6);
        assert_eq!(out.counters.delay_max, 7);
        assert!((out.elapsed_s - 2.0).abs() < 1e-12);
        assert!(out.raw_param[..8].iter().all(|&v| v == 1.0));
        assert!(out.raw_param[8..].iter().all(|&v| v == 2.0));
        let last = out.trace.last().expect("final sample");
        assert!(last.objective.is_finite());
    }
}
