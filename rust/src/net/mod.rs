//! Distributed delayed-update transport: the wire codec and the TCP
//! serve/worker roles.
//!
//! This subsystem turns the in-process delayed-update framework (paper
//! §2.3/§3.4, [`crate::coordinator::apbcfw`]) into a deployable
//! server/worker system over `std::net::TcpStream`:
//!
//! - [`wire`] — the versioned, length-prefixed binary codec for the
//!   handshake, parameter snapshots (full or dirty-range delta), and
//!   multi-block oracle payloads. Sparse payloads ship as their
//!   `(idx, val, dim)` triple — never densified on the wire. The
//!   normative spec is `docs/WIRE.md`.
//! - [`server`] — the `serve` role: hosts the delayed-update server loop,
//!   reusing the [`crate::coordinator::buffer::BatchAssembler`]
//!   collision/assembly machinery, stamping every applied update with its
//!   observed delay (the expected-delay counters), and answering snapshot
//!   pulls with deltas when its dirty-range log covers the gap.
//! - [`worker`] — the `worker` role: connects, rebuilds the problem from
//!   the handshake config, and streams batched oracles.
//!
//! Both roles lower through the same [`crate::run::RunSpec`] as every
//! other engine: `apbcfw serve` validates the spec exactly like
//! `apbcfw solve --mode async` (the CLI surface), and
//! [`server::solve_loopback`] self-hosts the whole fleet over 127.0.0.1 in
//! one process — the mode the distributed==in-process equivalence tests
//! in `rust/tests/net_transport.rs` pin (bit-identical to the sequential
//! delayed engine at one worker, tolerance-bounded beyond).
#![deny(missing_docs)]

pub mod server;
pub mod wire;
pub mod worker;

pub use server::{serve, solve_loopback, BoundServer};
pub use worker::{run_with_retry, WorkerSummary};

use crate::problems::PayloadMode;
use std::ops::Range;

/// Wire tag for a [`PayloadMode`] (`Hello.payload_mode`): 0 auto, 1
/// dense, 2 sparse.
pub fn payload_mode_tag(mode: PayloadMode) -> u8 {
    match mode {
        PayloadMode::Auto => 0,
        PayloadMode::Dense => 1,
        PayloadMode::Sparse => 2,
    }
}

/// Inverse of [`payload_mode_tag`]; `None` for an unknown tag.
pub fn payload_mode_from_tag(tag: u8) -> Option<PayloadMode> {
    match tag {
        0 => Some(PayloadMode::Auto),
        1 => Some(PayloadMode::Dense),
        2 => Some(PayloadMode::Sparse),
        _ => None,
    }
}

/// Rng stream a network worker derives from its id: `2 + id`. Worker 0
/// shares the sequential delayed engine's stream
/// ([`crate::solver::delayed`] draws from `Pcg64::new(seed, 2)`), which is
/// what makes the one-worker loopback solve replay that engine
/// draw-for-draw.
pub fn worker_rng_stream(worker_id: u32) -> u64 {
    2 + worker_id as u64
}

/// Sort and coalesce overlapping/adjacent index ranges — the dirty-range
/// merge behind delta snapshots (overlapping block writes collapse to one
/// wire run).
pub(crate) fn merge_ranges(mut ranges: Vec<Range<usize>>) -> Vec<Range<usize>> {
    ranges.sort_unstable_by_key(|r| r.start);
    let mut merged: Vec<Range<usize>> = Vec::with_capacity(ranges.len());
    for r in ranges {
        if r.is_empty() {
            continue;
        }
        match merged.last_mut() {
            Some(last) if r.start <= last.end => {
                last.end = last.end.max(r.end);
            }
            _ => merged.push(r),
        }
    }
    merged
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_mode_tags_roundtrip() {
        for mode in [PayloadMode::Auto, PayloadMode::Dense, PayloadMode::Sparse]
        {
            assert_eq!(payload_mode_from_tag(payload_mode_tag(mode)), Some(mode));
        }
        assert_eq!(payload_mode_from_tag(9), None);
    }

    #[test]
    fn worker_zero_shares_the_delayed_engine_stream() {
        assert_eq!(worker_rng_stream(0), 2);
        assert_eq!(worker_rng_stream(3), 5);
    }

    #[test]
    fn merge_ranges_coalesces() {
        assert_eq!(
            merge_ranges(vec![4..6, 0..2, 5..8, 2..3, 10..10]),
            vec![0..3, 4..8]
        );
        assert!(merge_ranges(vec![]).is_empty());
    }
}
