//! Distributed delayed-update transport: the wire codec and the TCP
//! serve/worker roles.
//!
//! This subsystem turns the in-process delayed-update framework (paper
//! §2.3/§3.4, [`crate::coordinator::apbcfw`]) into a deployable
//! server/worker system over `std::net::TcpStream`:
//!
//! - [`wire`] — the versioned, length-prefixed binary codec for the
//!   handshake, parameter snapshots (full or dirty-range delta), and
//!   multi-block oracle payloads. Sparse payloads ship as their
//!   `(idx, val, dim)` triple — never densified on the wire. The
//!   normative spec is `docs/WIRE.md`.
//! - [`server`] — the `serve` role: hosts the delayed-update server loop,
//!   reusing the [`crate::coordinator::buffer::BatchAssembler`]
//!   collision/assembly machinery, stamping every applied update with its
//!   observed delay (the expected-delay counters), and answering snapshot
//!   pulls with deltas when its dirty-range log covers the gap.
//! - [`worker`] — the `worker` role: connects, rebuilds the problem from
//!   the handshake config, and streams batched oracles; on a mid-run
//!   disconnect [`worker::run_resilient`] reconnects with jittered
//!   exponential backoff and rejoins under a fresh server-issued id.
//! - [`chaos`] — wire-level fault injection (`run.chaos`): heavy-tailed
//!   delay, frame reordering, frame drop, and abrupt disconnect, so the
//!   paper's Fig 3 straggler robustness replays over real sockets.
//! - [`shard`] — the sharded parameter plane (`run.shards`): a
//!   [`ShardPlan`] carves the blocks and the parameter vector into
//!   contiguous per-shard spans, each hosted by its own serve loop;
//!   workers route Update frames by block owner and fan snapshot pulls
//!   out to every shard under a per-shard version vector.
//!
//! Both roles lower through the same [`crate::run::RunSpec`] as every
//! other engine: `apbcfw serve` validates the spec exactly like
//! `apbcfw solve --mode async` (the CLI surface), and
//! [`server::solve_loopback`] self-hosts the whole fleet over 127.0.0.1 in
//! one process — the mode the distributed==in-process equivalence tests
//! in `rust/tests/net_transport.rs` pin (bit-identical to the sequential
//! delayed engine at one worker, tolerance-bounded beyond).
#![deny(missing_docs)]

pub mod chaos;
pub mod checkpoint;
pub mod server;
pub mod shard;
pub mod wire;
pub mod worker;

pub use chaos::{ChaosSpec, ChaosStream};
pub use server::{serve, solve_loopback, BoundServer};
pub use shard::{ShardInfo, ShardPlan};
pub use wire::WireMode;
pub use worker::{run_resilient, run_with_retry, WorkerSummary};

use crate::problems::PayloadMode;
use crate::util::config::Config;
use anyhow::{anyhow, bail, ensure, Result};
use std::ops::Range;
use std::time::Duration;

/// Fleet-management knobs shared by the serve role and — via the
/// handshake's flattened config — every worker: parsed once, validated
/// strictly at `apbcfw serve` bind time so a typo fails fast instead of
/// silently running a different experiment.
#[derive(Debug, Clone, PartialEq)]
pub struct NetOptions {
    /// `run.accept_timeout_secs` (default 30): how long the server waits
    /// for its initial fleet, and — the elastic generalization — how long
    /// it tolerates a momentarily *empty* fleet mid-run (every worker
    /// dead, none yet rejoined) before abandoning the run.
    pub accept_timeout: Duration,
    /// `run.liveness_ms` (default 0 = disabled): declare a connection
    /// dead after this long without a frame, requeueing its in-flight
    /// blocks. `None` also disables worker heartbeats — the pinned
    /// bit-identical no-chaos path exchanges exactly the v1 frames.
    pub liveness: Option<Duration>,
    /// Parsed `run.chaos` fault-injection spec (default: no faults).
    pub chaos: ChaosSpec,
    /// `run.shards` (default 1): number of serve shards the parameter
    /// plane is split across. 1 is the unsharded server, pinned
    /// bit-identical to protocol v2 behavior; `S > 1` spawns S shard
    /// loops per [`ShardPlan`].
    pub shards: usize,
    /// `run.shard_id` (default unset): host only this shard of the plan
    /// — the multi-process deployment, one `apbcfw serve --shard-id I`
    /// per shard. Unset hosts every shard in-process.
    pub shard_id: Option<usize>,
    /// `run.wire` (default `exact`): the v4 wire-encoding mode for
    /// update payload values and snapshot bodies. The knob rides to
    /// workers in the Hello config entries, so both ends resolve the
    /// same mode from the same source; `exact` keeps every body
    /// byte-identical to protocol v3.
    pub wire: WireMode,
    /// `run.checkpoint_every` (default 0 = off): write a durable
    /// per-shard [`checkpoint::Checkpoint`] every this many applied
    /// updates. 0 keeps the serve loop byte- and behavior-identical to
    /// the checkpoint-less v4 fleet; any positive cadence requires
    /// `run.checkpoint_dir`.
    pub checkpoint_every: u64,
    /// `run.checkpoint_dir` (default unset): directory holding the
    /// per-shard `shard-<s>.ckpt` files. Setting it (without `restore`)
    /// also arms fingerprint-validated auto-restore: a valid checkpoint
    /// of the same run found at bind is resumed from.
    pub checkpoint_dir: Option<String>,
    /// `run.restore` (default false): explicitly request a resume from
    /// `run.checkpoint_dir`. Restore never aborts a run — a missing,
    /// corrupt, or foreign checkpoint logs a fresh-start fallback.
    pub restore: bool,
}

impl Default for NetOptions {
    fn default() -> Self {
        Self {
            accept_timeout: Duration::from_secs(30),
            liveness: None,
            chaos: ChaosSpec::default(),
            shards: 1,
            shard_id: None,
            wire: WireMode::Exact,
            checkpoint_every: 0,
            checkpoint_dir: None,
            restore: false,
        }
    }
}

impl NetOptions {
    /// Parse and strictly validate the `run.{accept_timeout_secs,
    /// liveness_ms, chaos, shards, shard_id, wire, checkpoint_every,
    /// checkpoint_dir, restore}` knobs.
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let accept_timeout = match cfg.get("run.accept_timeout_secs") {
            None => Duration::from_secs(30),
            Some(v) => {
                let secs: f64 = v.parse().map_err(|_| {
                    anyhow!("run.accept_timeout_secs: bad number {v:?}")
                })?;
                ensure!(
                    secs.is_finite() && secs > 0.0,
                    "run.accept_timeout_secs must be finite and > 0, \
                     got {v}"
                );
                Duration::from_secs_f64(secs)
            }
        };
        let liveness = match cfg.get("run.liveness_ms") {
            None => None,
            Some(v) => {
                let ms: u64 = v.parse().map_err(|_| {
                    anyhow!(
                        "run.liveness_ms must be a nonnegative integer \
                         millisecond count, got {v:?}"
                    )
                })?;
                (ms > 0).then(|| Duration::from_millis(ms))
            }
        };
        let chaos = ChaosSpec::parse(cfg.get("run.chaos").unwrap_or("none"))?;
        let shards = match cfg.get("run.shards") {
            None => 1,
            Some(v) => {
                let s: usize = v.parse().map_err(|_| {
                    anyhow!("run.shards must be a positive integer, got {v:?}")
                })?;
                ensure!(s >= 1, "run.shards must be >= 1, got {v}");
                s
            }
        };
        let shard_id = match cfg.get("run.shard_id") {
            None => None,
            Some(v) => {
                let id: usize = v.parse().map_err(|_| {
                    anyhow!(
                        "run.shard_id must be a nonnegative integer, got {v:?}"
                    )
                })?;
                ensure!(
                    shards > 1,
                    "run.shard_id only applies to sharded serves \
                     (run.shards > 1)"
                );
                ensure!(
                    id < shards,
                    "run.shard_id = {id} out of range for run.shards = \
                     {shards}"
                );
                Some(id)
            }
        };
        let wire = WireMode::parse(&cfg.get_or("run.wire", "exact"))?;
        let checkpoint_every = match cfg.get("run.checkpoint_every") {
            None => 0,
            Some(v) => v.parse::<u64>().map_err(|_| {
                anyhow!(
                    "run.checkpoint_every must be a nonnegative integer \
                     count of applied updates (0 = off), got {v:?}"
                )
            })?,
        };
        let checkpoint_dir =
            cfg.get("run.checkpoint_dir").map(|v| v.to_string());
        if let Some(d) = checkpoint_dir.as_deref() {
            ensure!(
                !d.trim().is_empty(),
                "run.checkpoint_dir must not be empty when set"
            );
        }
        ensure!(
            checkpoint_every == 0 || checkpoint_dir.is_some(),
            "run.checkpoint_every = {checkpoint_every} needs \
             run.checkpoint_dir to say where checkpoints go"
        );
        let restore = match cfg.get("run.restore") {
            None => false,
            Some(v) => match v {
                "true" | "1" => true,
                "false" | "0" => false,
                other => bail!(
                    "run.restore must be true or false, got {other:?}"
                ),
            },
        };
        ensure!(
            !restore || checkpoint_dir.is_some(),
            "run.restore needs run.checkpoint_dir to restore from"
        );
        // The adaptive batch controller retunes tau_w from live pull
        // latencies, so the fan-out is no longer a session constant.
        // Both crash recovery (`resume_draws` divides the checkpointed
        // oracle count by a FIXED batch to realign worker rngs) and the
        // sharded plane (per-shard requeue quotas derive from the
        // announced fan-out) bake that constant in — reject the
        // combinations instead of silently mis-resuming or mis-counting.
        // Parsed here (not just in RunSpec) because both serve bind and
        // the worker handshake validate through this path.
        if let crate::sim::adapt::BatchPolicy::Auto { .. } =
            crate::sim::adapt::AdaptSpec::from_config(cfg)?.batch
        {
            ensure!(
                shards == 1,
                "run.adapt.batch = auto is incompatible with \
                 run.shards > 1 (shard requeue quotas assume the \
                 announced fixed fan-out)"
            );
            ensure!(
                checkpoint_dir.is_none() && !restore,
                "run.adapt.batch = auto is incompatible with \
                 checkpoint/restore (rng realignment after a restore \
                 assumes a fixed fan-out batch)"
            );
        }
        Ok(Self {
            accept_timeout,
            liveness,
            chaos,
            shards,
            shard_id,
            wire,
            checkpoint_every,
            checkpoint_dir,
            restore,
        })
    }

    /// Heartbeat period a worker derives from the liveness timeout: a
    /// third of it, so two heartbeats can be lost before the server
    /// declares the worker dead. `None` when liveness is disabled.
    pub fn heartbeat_period(&self) -> Option<Duration> {
        self.liveness.map(|d| d / 3)
    }
}

/// Wire tag for a [`PayloadMode`] (`Hello.payload_mode`): 0 auto, 1
/// dense, 2 sparse.
pub fn payload_mode_tag(mode: PayloadMode) -> u8 {
    match mode {
        PayloadMode::Auto => 0,
        PayloadMode::Dense => 1,
        PayloadMode::Sparse => 2,
    }
}

/// Inverse of [`payload_mode_tag`]; `None` for an unknown tag.
pub fn payload_mode_from_tag(tag: u8) -> Option<PayloadMode> {
    match tag {
        0 => Some(PayloadMode::Auto),
        1 => Some(PayloadMode::Dense),
        2 => Some(PayloadMode::Sparse),
        _ => None,
    }
}

/// The one definition site of the worker-id → rng-stream derivation:
/// `2 + id`. Worker 0 shares the sequential delayed engine's stream
/// ([`crate::solver::delayed`] draws from
/// `Pcg64::new(seed, rng_stream_for(0))`), which is what makes the
/// one-worker loopback solve replay that engine draw-for-draw. Every
/// consumer — the worker solve loops (sharded or not), the serve role's
/// handshake docs, and the sequential delayed engine — derives its
/// stream here so shard code can't drift from it.
pub fn rng_stream_for(worker_id: u32) -> u64 {
    2 + worker_id as u64
}

/// Sort and coalesce overlapping/adjacent index ranges — the dirty-range
/// merge behind delta snapshots (overlapping block writes collapse to one
/// wire run).
pub(crate) fn merge_ranges(mut ranges: Vec<Range<usize>>) -> Vec<Range<usize>> {
    ranges.sort_unstable_by_key(|r| r.start);
    let mut merged: Vec<Range<usize>> = Vec::with_capacity(ranges.len());
    for r in ranges {
        if r.is_empty() {
            continue;
        }
        match merged.last_mut() {
            Some(last) if r.start <= last.end => {
                last.end = last.end.max(r.end);
            }
            _ => merged.push(r),
        }
    }
    merged
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_mode_tags_roundtrip() {
        for mode in [PayloadMode::Auto, PayloadMode::Dense, PayloadMode::Sparse]
        {
            assert_eq!(payload_mode_from_tag(payload_mode_tag(mode)), Some(mode));
        }
        assert_eq!(payload_mode_from_tag(9), None);
    }

    #[test]
    fn worker_zero_shares_the_delayed_engine_stream() {
        assert_eq!(rng_stream_for(0), 2);
        assert_eq!(rng_stream_for(3), 5);
    }

    #[test]
    fn net_options_default_and_parse() {
        let opts = NetOptions::from_config(&Config::new()).unwrap();
        assert_eq!(opts, NetOptions::default());
        assert_eq!(opts.accept_timeout, Duration::from_secs(30));
        assert_eq!(opts.liveness, None);
        assert_eq!(opts.heartbeat_period(), None);
        assert!(opts.chaos.is_noop());

        let mut cfg = Config::new();
        cfg.set("run.accept_timeout_secs", "1.5");
        cfg.set("run.liveness_ms", "300");
        cfg.set("run.chaos", "drop:0.25");
        let opts = NetOptions::from_config(&cfg).unwrap();
        assert_eq!(opts.accept_timeout, Duration::from_secs_f64(1.5));
        assert_eq!(opts.liveness, Some(Duration::from_millis(300)));
        assert_eq!(opts.heartbeat_period(), Some(Duration::from_millis(100)));
        assert_eq!(opts.chaos.drop_p, 0.25);
        assert_eq!(opts.shards, 1);
        assert_eq!(opts.shard_id, None);

        let mut cfg = Config::new();
        cfg.set("run.shards", "3");
        cfg.set("run.shard_id", "2");
        let opts = NetOptions::from_config(&cfg).unwrap();
        assert_eq!(opts.shards, 3);
        assert_eq!(opts.shard_id, Some(2));

        // run.wire defaults to exact and parses the v4 vocabulary.
        assert_eq!(opts.wire, WireMode::Exact);
        for (text, mode) in [
            ("exact", WireMode::Exact),
            ("f16", WireMode::F16),
            ("q8", WireMode::Q8),
        ] {
            let mut cfg = Config::new();
            cfg.set("run.wire", text);
            assert_eq!(NetOptions::from_config(&cfg).unwrap().wire, mode);
        }

        // liveness_ms = 0 means disabled, not a zero timeout.
        let mut cfg = Config::new();
        cfg.set("run.liveness_ms", "0");
        assert_eq!(NetOptions::from_config(&cfg).unwrap().liveness, None);

        // Checkpointing defaults off; a cadence + dir parses; restore
        // accepts the boolean vocabulary.
        assert_eq!(NetOptions::default().checkpoint_every, 0);
        assert_eq!(NetOptions::default().checkpoint_dir, None);
        assert!(!NetOptions::default().restore);
        let mut cfg = Config::new();
        cfg.set("run.checkpoint_every", "50");
        cfg.set("run.checkpoint_dir", "/tmp/ck");
        cfg.set("run.restore", "true");
        let opts = NetOptions::from_config(&cfg).unwrap();
        assert_eq!(opts.checkpoint_every, 50);
        assert_eq!(opts.checkpoint_dir.as_deref(), Some("/tmp/ck"));
        assert!(opts.restore);
        // A dir alone (auto-restore armed, no cadence) is valid.
        let mut cfg = Config::new();
        cfg.set("run.checkpoint_dir", "/tmp/ck");
        let opts = NetOptions::from_config(&cfg).unwrap();
        assert_eq!(opts.checkpoint_every, 0);
        assert!(!opts.restore);
    }

    #[test]
    fn net_options_reject_bad_knobs() {
        for (key, bad) in [
            ("run.accept_timeout_secs", "0"),
            ("run.accept_timeout_secs", "-3"),
            ("run.accept_timeout_secs", "inf"),
            ("run.accept_timeout_secs", "soon"),
            ("run.liveness_ms", "-5"),
            ("run.liveness_ms", "1.5"),
            ("run.chaos", "bogus"),
            ("run.shards", "0"),
            ("run.shards", "-2"),
            ("run.shards", "two"),
            ("run.shard_id", "0"), // requires run.shards > 1
            ("run.wire", "bogus"),
            ("run.wire", "F16"),
            ("run.checkpoint_every", "-1"),
            ("run.checkpoint_every", "1.5"),
            ("run.checkpoint_every", "often"),
            ("run.checkpoint_every", "50"), // requires checkpoint_dir
            ("run.checkpoint_dir", "  "),
            ("run.restore", "true"), // requires checkpoint_dir
            ("run.restore", "yes"),
        ] {
            let mut cfg = Config::new();
            cfg.set(key, bad);
            assert!(
                NetOptions::from_config(&cfg).is_err(),
                "{key}={bad} must be rejected"
            );
        }
    }

    #[test]
    fn adaptive_batch_rejects_incompatible_combinations() {
        let mut cfg = Config::new();
        cfg.set("run.adapt.batch", "auto:1:8");
        assert!(NetOptions::from_config(&cfg).is_ok());
        cfg.set("run.shards", "2");
        let err = NetOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("run.adapt.batch"), "{err}");
        assert!(err.contains("shards"), "{err}");
        let mut cfg = Config::new();
        cfg.set("run.adapt.batch", "auto:1:8");
        cfg.set("run.checkpoint_dir", "/tmp/ck");
        let err = NetOptions::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("run.adapt.batch"), "{err}");
        assert!(err.contains("checkpoint"), "{err}");
        // A malformed value fails parse before any combination check.
        let mut cfg = Config::new();
        cfg.set("run.adapt.batch", "auto:8:2");
        assert!(NetOptions::from_config(&cfg).is_err());
    }

    #[test]
    fn merge_ranges_coalesces() {
        assert_eq!(
            merge_ranges(vec![4..6, 0..2, 5..8, 2..3, 10..10]),
            vec![0..3, 4..8]
        );
        assert!(merge_ranges(vec![]).is_empty());
    }
}
