//! Command-line interface for the `apbcfw` launcher.
//!
//! Hand-rolled parser (no clap in the offline vendor set). Grammar:
//!
//! ```text
//! apbcfw exp <id|all> [--config FILE] [--set sect.key=val ...]
//! apbcfw solve <gfl|ssvm|multiclass|qp>
//!        [--mode seq|batch|delayed|pbcd|async|sync|lockfree]
//!        [--tau N] [--batch N] [--workers N] [--epochs F] [--seed N]
//!        [--line-search] [--straggler none|single:P|hetero:T|p1,p2,..]
//!        [--snapshot-mode torn|consistent] [--queue-factor N]
//!        [--config FILE] [--set sect.key=val ...]
//! apbcfw serve <problem> [--listen HOST:PORT] [--self-host]
//!        [--accept-timeout SECS] [--checkpoint-dir DIR]
//!        [--checkpoint-every N] [--restore] [solve flags]
//! apbcfw worker [--connect HOST:PORT] [--connect-timeout SECS]
//! apbcfw artifacts-check [--dir DIR]
//! apbcfw info
//! ```
//!
//! Every solve flag is sugar for a `--set run.<key>=<value>` override: the
//! launcher builds a [`crate::run::RunSpec`] from the layered config, so
//! flags, `--config` files and `--set` all reach the same knobs (and knobs
//! without dedicated flags — `run.weighted_averaging`, `run.delay`,
//! `run.work_multiplier`, ... — are always reachable through `--set`).

use crate::run::{ENGINE_NAMES, PROBLEM_NAMES};
use crate::util::config::Config;
use anyhow::{anyhow, bail, Result};

/// Parsed top-level command.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    /// Run a paper experiment by id.
    Exp { id: String },
    /// Run a single solve (spec in the layered config) and print a summary.
    Solve { problem: String },
    /// Host the distributed delayed-update server (`net::serve`): listen
    /// on `addr`, accept the spec's worker fleet, run the solve. With
    /// `self_host`, spawn the workers in-process over loopback TCP.
    Serve {
        /// Registered problem name.
        problem: String,
        /// Listen address (`host:port`; port 0 = ephemeral).
        addr: String,
        /// Run the worker fleet in this process (loopback demo mode).
        self_host: bool,
    },
    /// Join a serve-role host as a network worker (`net::worker`).
    Worker {
        /// Server address to connect to.
        addr: String,
        /// Window (seconds) to keep retrying a connect before giving up
        /// (`--connect-timeout`; also the reconnect window after a broken
        /// session).
        connect_timeout_secs: f64,
    },
    /// Load and compile every artifact in the manifest.
    ArtifactsCheck { dir: String },
    /// Print build/environment info.
    Info,
    /// Print usage.
    Help,
}

/// Full parse result: command + layered config.
#[derive(Debug)]
pub struct Cli {
    pub command: Command,
    pub config: Config,
}

/// Solve flags that lower to `run.*` config keys.
const SOLVE_FLAG_KEYS: &[(&str, &str)] = &[
    ("mode", "run.mode"),
    ("tau", "run.tau"),
    ("batch", "run.batch"),
    ("workers", "run.workers"),
    ("epochs", "run.epochs"),
    ("seed", "run.seed"),
    ("straggler", "run.straggler"),
    ("snapshot-mode", "run.snapshot_mode"),
    ("queue-factor", "run.queue_factor"),
    ("wire", "run.wire"),
];

/// Parse a timeout flag value: seconds, finite and strictly positive.
fn parse_secs(flag: &str, v: &str) -> Result<f64> {
    match v.parse::<f64>() {
        Ok(s) if s.is_finite() && s > 0.0 => Ok(s),
        _ => bail!("--{flag}: expected seconds > 0, got {v:?}"),
    }
}

/// Parse argv (excluding the binary name).
pub fn parse(args: &[String]) -> Result<Cli> {
    let mut config = Config::new();
    if args.is_empty() {
        return Ok(Cli {
            command: Command::Help,
            config,
        });
    }
    let sub = args[0].as_str();
    let rest = &args[1..];

    // Common flags: --config FILE and --set k=v (repeatable) anywhere.
    let mut positional: Vec<&str> = Vec::new();
    let mut flags: Vec<(&str, Option<&str>)> = Vec::new();
    let mut i = 0usize;
    while i < rest.len() {
        let a = rest[i].as_str();
        if let Some(name) = a.strip_prefix("--") {
            let takes_value = matches!(
                name,
                "config" | "set" | "dir" | "mode" | "tau" | "batch"
                    | "workers" | "epochs" | "seed" | "straggler"
                    | "snapshot-mode" | "queue-factor" | "listen" | "connect"
                    | "connect-timeout" | "accept-timeout" | "shards"
                    | "shard-id" | "wire" | "checkpoint-dir"
                    | "checkpoint-every"
            );
            if takes_value {
                let v = rest
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("--{name} needs a value"))?;
                flags.push((name, Some(v.as_str())));
                i += 2;
            } else {
                flags.push((name, None));
                i += 1;
            }
        } else {
            positional.push(a);
            i += 1;
        }
    }
    for (name, value) in &flags {
        match *name {
            "config" => {
                let path = value.unwrap();
                config.merge_str(&std::fs::read_to_string(path)?)
                    .map_err(|e| anyhow!("{path}: {e}"))?;
            }
            "set" => {
                let kv = value.unwrap();
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow!("--set expects key=value"))?;
                config.set(k.trim(), v.trim());
            }
            _ => {}
        }
    }
    let flag_val = |name: &str| -> Option<&str> {
        flags
            .iter()
            .rev()
            .find(|(n, _)| *n == name)
            .and_then(|(_, v)| *v)
    };
    let has_flag = |name: &str| flags.iter().any(|(n, _)| *n == name);

    let command = match sub {
        "exp" => {
            let id = positional
                .first()
                .ok_or_else(|| anyhow!("exp: missing experiment id"))?;
            Command::Exp { id: id.to_string() }
        }
        "solve" | "serve" => {
            let problem = positional
                .first()
                .ok_or_else(|| anyhow!("{sub}: missing problem name"))?
                .to_string();
            if !PROBLEM_NAMES.contains(&problem.as_str()) {
                bail!(
                    "{sub}: unknown problem {problem:?} \
                     (registered: {PROBLEM_NAMES:?})"
                );
            }
            if let Some(mode) = flag_val("mode") {
                if !ENGINE_NAMES.contains(&mode) {
                    bail!(
                        "{sub}: unknown mode {mode:?} \
                         (engines: {ENGINE_NAMES:?})"
                    );
                }
            }
            // Lower convenience flags onto the unified run.* keys; flags
            // are sugar for --set, applied after it so the explicit flag
            // wins over a conflicting --set of the same key. Numeric flags
            // are validated here so a typo gets the CLI's clean error
            // instead of a panic in the typed config accessors.
            for (flag, key) in SOLVE_FLAG_KEYS {
                if let Some(v) = flag_val(flag) {
                    let ok = match *flag {
                        "tau" | "batch" | "workers" | "queue-factor" => {
                            v.parse::<usize>().is_ok()
                        }
                        "seed" => v.parse::<u64>().is_ok(),
                        "epochs" => v.parse::<f64>().is_ok(),
                        _ => true,
                    };
                    if !ok {
                        bail!("--{flag}: invalid value {v:?}");
                    }
                    config.set(key, v);
                }
            }
            if has_flag("line-search") {
                config.set("run.line_search", "true");
            }
            // Historical launcher defaults, unless the user already chose.
            if config.get("run.epochs").is_none()
                && config.get("run.max_epochs").is_none()
            {
                config.set("run.epochs", "50");
            }
            if config.get("run.max_secs").is_none() {
                config.set("run.max_secs", "300");
            }
            if sub == "serve" {
                // The serve role hosts the async engine's delayed-update
                // loop; default the mode rather than making every serve
                // invocation spell it (an explicit non-async mode gets
                // `net::serve`'s clean rejection).
                if config.get("run.mode").is_none() {
                    config.set("run.mode", "async");
                }
                // --accept-timeout is sugar for the fleet-accept /
                // empty-fleet-grace knob; validate here for the CLI's
                // clean error, then lower to the config key `net::serve`
                // reads (`NetOptions::from_config`).
                if let Some(v) = flag_val("accept-timeout") {
                    parse_secs("accept-timeout", v)?;
                    config.set("run.accept_timeout_secs", v);
                }
                // --shards / --shard-id are sugar for the sharded
                // parameter plane knobs; validate the integer shape here
                // for the CLI's clean error, then lower to the config
                // keys `net::serve` reads and cross-validates
                // (`NetOptions::from_config` rejects shards < 1 and an
                // out-of-range or shard-less shard id).
                if let Some(v) = flag_val("shards") {
                    let s: usize = v.parse().map_err(|_| {
                        anyhow!("--shards must be a positive integer, got {v:?}")
                    })?;
                    if s < 1 {
                        bail!("--shards must be >= 1, got {v}");
                    }
                    config.set("run.shards", v);
                }
                if let Some(v) = flag_val("shard-id") {
                    let _: usize = v.parse().map_err(|_| {
                        anyhow!(
                            "--shard-id must be a nonnegative integer, \
                             got {v:?}"
                        )
                    })?;
                    config.set("run.shard_id", v);
                }
                // Crash-recovery sugar: --checkpoint-dir arms durable
                // per-shard checkpoints (and auto-restore on restart),
                // --checkpoint-every sets the write cadence in applied
                // updates (0 = off), --restore states explicit restore
                // intent. Lowered to the run.* keys `net::serve` reads
                // and cross-validates (`NetOptions::from_config` rejects
                // a cadence without a dir and a restore without a dir).
                if let Some(v) = flag_val("checkpoint-dir") {
                    if v.trim().is_empty() {
                        bail!("--checkpoint-dir needs a non-empty path");
                    }
                    config.set("run.checkpoint_dir", v);
                }
                if let Some(v) = flag_val("checkpoint-every") {
                    let _: u64 = v.parse().map_err(|_| {
                        anyhow!(
                            "--checkpoint-every must be a nonnegative \
                             integer count of applied updates, got {v:?}"
                        )
                    })?;
                    config.set("run.checkpoint_every", v);
                }
                if has_flag("restore") {
                    config.set("run.restore", "true");
                }
                let self_host = has_flag("self-host");
                let addr = flag_val("listen")
                    .unwrap_or(if self_host {
                        // Self-hosted runs pick an ephemeral port so demos
                        // and CI never collide on a fixed one.
                        "127.0.0.1:0"
                    } else {
                        "127.0.0.1:7878"
                    })
                    .to_string();
                Command::Serve {
                    problem,
                    addr,
                    self_host,
                }
            } else {
                Command::Solve { problem }
            }
        }
        "worker" => Command::Worker {
            addr: flag_val("connect").unwrap_or("127.0.0.1:7878").to_string(),
            connect_timeout_secs: match flag_val("connect-timeout") {
                Some(v) => parse_secs("connect-timeout", v)?,
                // Historical default: retry the connect for ~10 s.
                None => 10.0,
            },
        },
        "artifacts-check" => Command::ArtifactsCheck {
            dir: flag_val("dir").unwrap_or("artifacts").to_string(),
        },
        "info" => Command::Info,
        "help" | "--help" | "-h" => Command::Help,
        other => bail!("unknown command {other:?} (try `apbcfw help`)"),
    };
    Ok(Cli { command, config })
}

/// Usage text.
pub const USAGE: &str = "\
apbcfw — Asynchronous Parallel Block-Coordinate Frank-Wolfe (ICML 2016 repro)

USAGE:
  apbcfw exp <id|all> [--config FILE] [--set sect.key=val ...]
      ids: fig1a fig1b fig2a fig2b fig2c fig2d fig3a fig3b fig4 fig5
           ex1 ex2 d4 prop1
  apbcfw solve <gfl|ssvm|multiclass|qp>
         [--mode seq|batch|delayed|pbcd|async|sync|lockfree]
         [--tau N] [--batch N] [--workers N] [--epochs F] [--seed N]
         [--line-search] [--straggler none|single:P|hetero:T|p1,p2,..]
         [--snapshot-mode torn|consistent] [--queue-factor N]
         [--config FILE] [--set sect.key=val ...]
      --batch is the worker fan-out tau_w (threaded modes only): blocks
      each worker solves per shared-parameter snapshot.
      every flag is sugar for --set run.<key>=<val>; further knobs
      (run.payload=auto|dense|sparse, run.delay, run.weighted_averaging,
      run.work_multiplier, run.eps_gap, ...) are reachable through
      --set / --config only.
      delay-adaptive control (defaults bit-identical to the fixed
      schedules): --set run.adapt.step=off|kappa damps the step
      schedule by the observed/expected delay ratio,
      --set run.adapt.drop=k2|quantile:Q tracks the drop threshold to
      a running delay quantile, --set run.adapt.batch=off|auto:MIN:MAX
      lets net workers retune their fan-out tau_w from snapshot-pull
      latency (serve role only; incompatible with shards > 1 and
      checkpoint/restore).
  apbcfw serve <gfl|ssvm|multiclass|qp> [--listen HOST:PORT] [--self-host]
         [--accept-timeout SECS] [--shards S] [--shard-id I]
         [--checkpoint-dir DIR] [--checkpoint-every N] [--restore]
         [solve flags as above; --mode defaults to async]
      host the distributed delayed-update server: workers connect over
      TCP (wire protocol: docs/WIRE.md), pull parameter snapshots, and
      stream sparse oracle payloads back. --workers N is the fleet size
      the server waits for; late workers may still join mid-run, and
      dead ones have their in-flight blocks requeued (liveness window:
      --set run.liveness_ms=N). --accept-timeout bounds both the initial
      fleet wait and how long an empty fleet is tolerated mid-run
      (default 30). fault injection: --set run.chaos=<spec> (see
      docs/WIRE.md). --self-host runs the fleet in-process over
      127.0.0.1 (single-machine demo of the full wire path).
      --shards S splits the parameter plane into S block-contiguous
      shards, shard s listening on PORT+s; workers learn the plan from
      the handshake and route each update to its block's owner.
      --shard-id I hosts only shard I in this process (one serve
      process per shard; needs an explicit --listen base port).
      --wire exact|f16|q8 picks the wire encoding (sugar for
      --set run.wire=...): exact (default) ships f32 bits unchanged;
      f16/q8 quantize sparse update values and compress snapshot
      bodies losslessly (docs/WIRE.md §4).
      crash recovery: --checkpoint-dir DIR writes a durable, CRC-checked
      checkpoint per shard every --checkpoint-every N applied updates
      (default 0 = off) and auto-restores from it on restart — the
      restarted shard resumes at the checkpointed iteration under a
      bumped generation, and updates computed against pre-crash state
      are fenced (docs/WIRE.md §5). --restore states the intent
      explicitly (same behavior, plus a log line when no usable
      checkpoint is found). deterministic crash injection for drills:
      --set run.chaos=crash:K aborts each shard's first generation
      after K applied updates.
  apbcfw worker [--connect HOST:PORT] [--connect-timeout SECS]
      join a serve host as a network worker. retries the connect with
      jittered backoff for --connect-timeout seconds (default 10) so
      start order does not matter, and reconnects the same way after a
      transient disconnect mid-run.
  apbcfw artifacts-check [--dir DIR]
  apbcfw info
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_exp() {
        let cli = parse(&sv(&["exp", "fig1a"])).unwrap();
        assert_eq!(
            cli.command,
            Command::Exp {
                id: "fig1a".into()
            }
        );
    }

    #[test]
    fn solve_flags_lower_to_run_keys() {
        let cli = parse(&sv(&[
            "solve",
            "gfl",
            "--mode",
            "async",
            "--tau",
            "8",
            "--batch",
            "4",
            "--workers",
            "4",
            "--seed",
            "11",
            "--straggler",
            "single:0.25",
            "--snapshot-mode",
            "consistent",
            "--queue-factor",
            "16",
            "--line-search",
        ]))
        .unwrap();
        assert_eq!(
            cli.command,
            Command::Solve {
                problem: "gfl".into()
            }
        );
        let c = &cli.config;
        assert_eq!(c.get("run.mode"), Some("async"));
        assert_eq!(c.get_usize("run.tau", 0), 8);
        assert_eq!(c.get_usize("run.batch", 0), 4);
        assert_eq!(c.get_usize("run.workers", 0), 4);
        assert_eq!(c.get_u64("run.seed", 0), 11);
        assert_eq!(c.get("run.straggler"), Some("single:0.25"));
        assert_eq!(c.get("run.snapshot_mode"), Some("consistent"));
        assert_eq!(c.get_usize("run.queue_factor", 0), 16);
        assert!(c.get_bool("run.line_search", false));
    }

    #[test]
    fn solve_parses_into_a_valid_run_spec() {
        // The full path the launcher takes: flags -> config -> RunSpec.
        let cli = parse(&sv(&[
            "solve", "qp", "--mode", "delayed", "--tau", "2", "--set",
            "run.delay=poisson:5",
        ]))
        .unwrap();
        let spec = crate::run::RunSpec::from_config(&cli.config).unwrap();
        assert_eq!(spec.engine.name(), "delayed");
        assert_eq!(spec.tau, 2);
        // CLI default budget applied.
        assert_eq!(spec.stop.max_epochs, 50.0);
        assert_eq!(spec.stop.max_secs, 300.0);
    }

    #[test]
    fn wire_flag_lowers_to_run_wire_and_validates_in_spec() {
        let cli = parse(&sv(&[
            "serve", "qp", "--self-host", "--wire", "q8",
        ]))
        .unwrap();
        assert_eq!(cli.config.get("run.wire"), Some("q8"));
        // serve defaults run.mode=async, so the full lowering validates.
        assert!(crate::run::RunSpec::from_config(&cli.config).is_ok());
        // A typo'd value parses at the CLI (the flag is plain sugar) but
        // fails the spec's strict validation.
        let cli = parse(&sv(&[
            "serve", "qp", "--self-host", "--wire", "bogus",
        ]))
        .unwrap();
        let err = crate::run::RunSpec::from_config(&cli.config)
            .unwrap_err()
            .to_string();
        assert!(err.contains("run.wire"), "{err}");
    }

    #[test]
    fn flag_beats_set_for_same_key() {
        let cli = parse(&sv(&[
            "solve", "gfl", "--set", "run.tau=3", "--tau", "9",
        ]))
        .unwrap();
        assert_eq!(cli.config.get_usize("run.tau", 0), 9);
    }

    #[test]
    fn set_overrides_config() {
        let cli =
            parse(&sv(&["exp", "fig4", "--set", "fig4.kappas=0,5"])).unwrap();
        assert_eq!(
            cli.config.get_f64_list("fig4.kappas", &[]),
            vec![0.0, 5.0]
        );
    }

    #[test]
    fn explicit_budget_not_overridden_by_defaults() {
        let cli = parse(&sv(&[
            "solve", "gfl", "--set", "run.max_epochs=7",
        ]))
        .unwrap();
        let spec = crate::run::RunSpec::from_config(&cli.config).unwrap();
        assert_eq!(spec.stop.max_epochs, 7.0);
    }

    #[test]
    fn rejects_unknown_command_problem_and_mode() {
        assert!(parse(&sv(&["frobnicate"])).is_err());
        assert!(parse(&sv(&["solve", "nosuch"])).is_err());
        assert!(parse(&sv(&["solve", "gfl", "--mode", "warp"])).is_err());
    }

    #[test]
    fn rejects_non_numeric_flag_values_cleanly() {
        // A clean Err (not a panic in the config accessors), matching the
        // legacy parser's behaviour.
        for args in [
            ["solve", "gfl", "--tau", "abc"],
            ["solve", "gfl", "--batch", "-2"],
            ["solve", "gfl", "--workers", "two"],
            ["solve", "gfl", "--epochs", "lots"],
            ["solve", "gfl", "--seed", "-1"],
            ["solve", "gfl", "--queue-factor", "4x"],
        ] {
            assert!(parse(&sv(&args)).is_err(), "{args:?}");
        }
    }

    #[test]
    fn new_modes_accepted() {
        for mode in ["batch", "delayed", "pbcd"] {
            let cli = parse(&sv(&["solve", "gfl", "--mode", mode])).unwrap();
            assert_eq!(cli.config.get("run.mode"), Some(mode));
        }
    }

    #[test]
    fn empty_is_help() {
        let cli = parse(&[]).unwrap();
        assert_eq!(cli.command, Command::Help);
    }

    #[test]
    fn serve_defaults_async_mode_and_fixed_port() {
        let cli = parse(&sv(&["serve", "gfl", "--workers", "3"])).unwrap();
        assert_eq!(
            cli.command,
            Command::Serve {
                problem: "gfl".into(),
                addr: "127.0.0.1:7878".into(),
                self_host: false,
            }
        );
        assert_eq!(cli.config.get("run.mode"), Some("async"));
        assert_eq!(cli.config.get_usize("run.workers", 0), 3);
        // The solve budget defaults apply to serve too.
        assert_eq!(cli.config.get("run.epochs"), Some("50"));
    }

    #[test]
    fn serve_self_host_picks_ephemeral_port_and_listen_overrides() {
        let cli = parse(&sv(&["serve", "qp", "--self-host"])).unwrap();
        assert_eq!(
            cli.command,
            Command::Serve {
                problem: "qp".into(),
                addr: "127.0.0.1:0".into(),
                self_host: true,
            }
        );
        let cli = parse(&sv(&[
            "serve",
            "qp",
            "--self-host",
            "--listen",
            "127.0.0.1:9100",
        ]))
        .unwrap();
        match cli.command {
            Command::Serve { addr, .. } => {
                assert_eq!(addr, "127.0.0.1:9100")
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn serve_keeps_explicit_mode_for_net_to_validate() {
        // A non-async mode parses (the engine vocabulary is shared); the
        // serve role itself rejects it with a clean error at bind time.
        let cli = parse(&sv(&["serve", "gfl", "--mode", "sync"])).unwrap();
        assert_eq!(cli.config.get("run.mode"), Some("sync"));
        assert!(parse(&sv(&["serve", "gfl", "--mode", "warp"])).is_err());
        assert!(parse(&sv(&["serve", "nosuch"])).is_err());
    }

    #[test]
    fn worker_parses_connect_addr() {
        let cli = parse(&sv(&["worker"])).unwrap();
        assert_eq!(
            cli.command,
            Command::Worker {
                addr: "127.0.0.1:7878".into(),
                connect_timeout_secs: 10.0,
            }
        );
        let cli =
            parse(&sv(&["worker", "--connect", "10.0.0.5:7900"])).unwrap();
        assert_eq!(
            cli.command,
            Command::Worker {
                addr: "10.0.0.5:7900".into(),
                connect_timeout_secs: 10.0,
            }
        );
    }

    #[test]
    fn worker_connect_timeout_parses_and_validates() {
        let cli = parse(&sv(&["worker", "--connect-timeout", "2.5"])).unwrap();
        assert_eq!(
            cli.command,
            Command::Worker {
                addr: "127.0.0.1:7878".into(),
                connect_timeout_secs: 2.5,
            }
        );
        for bad in ["0", "-3", "inf", "NaN", "soon"] {
            assert!(
                parse(&sv(&["worker", "--connect-timeout", bad])).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn serve_accept_timeout_lowers_to_config_and_validates() {
        let cli =
            parse(&sv(&["serve", "gfl", "--accept-timeout", "1.5"])).unwrap();
        assert_eq!(cli.config.get("run.accept_timeout_secs"), Some("1.5"));
        // Unset flag leaves the key unset (serve's own default applies).
        let cli = parse(&sv(&["serve", "gfl"])).unwrap();
        assert_eq!(cli.config.get("run.accept_timeout_secs"), None);
        for bad in ["0", "-1", "inf", "never"] {
            assert!(
                parse(&sv(&["serve", "gfl", "--accept-timeout", bad]))
                    .is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn serve_shard_flags_lower_to_config_and_validate() {
        let cli = parse(&sv(&[
            "serve", "gfl", "--shards", "2", "--shard-id", "1",
        ]))
        .unwrap();
        assert_eq!(cli.config.get("run.shards"), Some("2"));
        assert_eq!(cli.config.get("run.shard_id"), Some("1"));
        // Unset flags leave the keys unset (serve defaults to one shard).
        let cli = parse(&sv(&["serve", "gfl"])).unwrap();
        assert_eq!(cli.config.get("run.shards"), None);
        assert_eq!(cli.config.get("run.shard_id"), None);
        for bad in ["0", "-2", "two", "1.5"] {
            assert!(
                parse(&sv(&["serve", "gfl", "--shards", bad])).is_err(),
                "--shards {bad}"
            );
        }
        for bad in ["-1", "one", "0.5"] {
            assert!(
                parse(&sv(&["serve", "gfl", "--shard-id", bad])).is_err(),
                "--shard-id {bad}"
            );
        }
    }

    #[test]
    fn serve_checkpoint_flags_lower_to_config_and_validate() {
        let cli = parse(&sv(&[
            "serve",
            "gfl",
            "--checkpoint-dir",
            "/tmp/ck",
            "--checkpoint-every",
            "50",
            "--restore",
        ]))
        .unwrap();
        assert_eq!(cli.config.get("run.checkpoint_dir"), Some("/tmp/ck"));
        assert_eq!(cli.config.get("run.checkpoint_every"), Some("50"));
        assert_eq!(cli.config.get("run.restore"), Some("true"));
        // Unset flags leave the keys unset: the serve default (no
        // checkpointing) stays byte-identical to a pre-v5 fleet.
        let cli = parse(&sv(&["serve", "gfl"])).unwrap();
        assert_eq!(cli.config.get("run.checkpoint_dir"), None);
        assert_eq!(cli.config.get("run.checkpoint_every"), None);
        assert_eq!(cli.config.get("run.restore"), None);
        // Bad shapes get the CLI's clean error, not a deep serve failure.
        for bad in ["-1", "often", "1.5"] {
            assert!(
                parse(&sv(&[
                    "serve", "gfl", "--checkpoint-every", bad
                ]))
                .is_err(),
                "--checkpoint-every {bad}"
            );
        }
        assert!(
            parse(&sv(&["serve", "gfl", "--checkpoint-dir", "  "])).is_err()
        );
    }
}
