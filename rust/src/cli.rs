//! Command-line interface for the `apbcfw` launcher.
//!
//! Hand-rolled parser (no clap in the offline vendor set). Grammar:
//!
//! ```text
//! apbcfw exp <id|all> [--config FILE] [--set sect.key=val ...]
//! apbcfw solve <gfl|ssvm|multiclass|qp> [--mode seq|async|sync|lockfree]
//!        [--tau N] [--workers N] [--epochs F] [--line-search]
//!        [--config FILE] [--set sect.key=val ...]
//! apbcfw artifacts-check [--dir DIR]
//! apbcfw info
//! ```

use crate::util::config::Config;
use anyhow::{anyhow, bail, Result};

/// Parsed top-level command.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    /// Run a paper experiment by id.
    Exp { id: String },
    /// Run a single solve and print a summary.
    Solve {
        problem: String,
        mode: String,
        tau: usize,
        workers: usize,
        epochs: f64,
        line_search: bool,
    },
    /// Load and compile every artifact in the manifest.
    ArtifactsCheck { dir: String },
    /// Print build/environment info.
    Info,
    /// Print usage.
    Help,
}

/// Full parse result: command + layered config.
#[derive(Debug)]
pub struct Cli {
    pub command: Command,
    pub config: Config,
}

/// Parse argv (excluding the binary name).
pub fn parse(args: &[String]) -> Result<Cli> {
    let mut config = Config::new();
    if args.is_empty() {
        return Ok(Cli {
            command: Command::Help,
            config,
        });
    }
    let sub = args[0].as_str();
    let rest = &args[1..];

    // Common flags: --config FILE and --set k=v (repeatable) anywhere.
    let mut positional: Vec<&str> = Vec::new();
    let mut flags: Vec<(&str, Option<&str>)> = Vec::new();
    let mut i = 0usize;
    while i < rest.len() {
        let a = rest[i].as_str();
        if let Some(name) = a.strip_prefix("--") {
            let takes_value = matches!(
                name,
                "config" | "set" | "dir" | "mode" | "tau" | "workers"
                    | "epochs"
            );
            if takes_value {
                let v = rest
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("--{name} needs a value"))?;
                flags.push((name, Some(v.as_str())));
                i += 2;
            } else {
                flags.push((name, None));
                i += 1;
            }
        } else {
            positional.push(a);
            i += 1;
        }
    }
    for (name, value) in &flags {
        match *name {
            "config" => {
                let path = value.unwrap();
                config.merge_str(&std::fs::read_to_string(path)?)
                    .map_err(|e| anyhow!("{path}: {e}"))?;
            }
            "set" => {
                let kv = value.unwrap();
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow!("--set expects key=value"))?;
                config.set(k.trim(), v.trim());
            }
            _ => {}
        }
    }
    let flag_val = |name: &str| -> Option<&str> {
        flags
            .iter()
            .rev()
            .find(|(n, _)| *n == name)
            .and_then(|(_, v)| *v)
    };
    let has_flag = |name: &str| flags.iter().any(|(n, _)| *n == name);

    let command = match sub {
        "exp" => {
            let id = positional
                .first()
                .ok_or_else(|| anyhow!("exp: missing experiment id"))?;
            Command::Exp { id: id.to_string() }
        }
        "solve" => {
            let problem = positional
                .first()
                .ok_or_else(|| anyhow!("solve: missing problem name"))?
                .to_string();
            if !["gfl", "ssvm", "multiclass", "qp"].contains(&problem.as_str())
            {
                bail!("solve: unknown problem {problem:?}");
            }
            let mode =
                flag_val("mode").unwrap_or("seq").to_string();
            if !["seq", "async", "sync", "lockfree"].contains(&mode.as_str())
            {
                bail!("solve: unknown mode {mode:?}");
            }
            Command::Solve {
                problem,
                mode,
                tau: flag_val("tau")
                    .map(|v| v.parse())
                    .transpose()?
                    .unwrap_or(1),
                workers: flag_val("workers")
                    .map(|v| v.parse())
                    .transpose()?
                    .unwrap_or(2),
                epochs: flag_val("epochs")
                    .map(|v| v.parse())
                    .transpose()?
                    .unwrap_or(50.0),
                line_search: has_flag("line-search"),
            }
        }
        "artifacts-check" => Command::ArtifactsCheck {
            dir: flag_val("dir").unwrap_or("artifacts").to_string(),
        },
        "info" => Command::Info,
        "help" | "--help" | "-h" => Command::Help,
        other => bail!("unknown command {other:?} (try `apbcfw help`)"),
    };
    Ok(Cli { command, config })
}

/// Usage text.
pub const USAGE: &str = "\
apbcfw — Asynchronous Parallel Block-Coordinate Frank-Wolfe (ICML 2016 repro)

USAGE:
  apbcfw exp <id|all> [--config FILE] [--set sect.key=val ...]
      ids: fig1a fig1b fig2a fig2b fig2c fig2d fig3a fig3b fig4 fig5
           ex1 ex2 d4 prop1
  apbcfw solve <gfl|ssvm|multiclass|qp> [--mode seq|async|sync|lockfree]
         [--tau N] [--workers N] [--epochs F] [--line-search]
  apbcfw artifacts-check [--dir DIR]
  apbcfw info
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_exp() {
        let cli = parse(&sv(&["exp", "fig1a"])).unwrap();
        assert_eq!(
            cli.command,
            Command::Exp {
                id: "fig1a".into()
            }
        );
    }

    #[test]
    fn parses_solve_with_flags() {
        let cli = parse(&sv(&[
            "solve",
            "gfl",
            "--mode",
            "async",
            "--tau",
            "8",
            "--workers",
            "4",
            "--line-search",
        ]))
        .unwrap();
        match cli.command {
            Command::Solve {
                problem,
                mode,
                tau,
                workers,
                line_search,
                ..
            } => {
                assert_eq!(problem, "gfl");
                assert_eq!(mode, "async");
                assert_eq!(tau, 8);
                assert_eq!(workers, 4);
                assert!(line_search);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn set_overrides_config() {
        let cli =
            parse(&sv(&["exp", "fig4", "--set", "fig4.kappas=0,5"])).unwrap();
        assert_eq!(
            cli.config.get_f64_list("fig4.kappas", &[]),
            vec![0.0, 5.0]
        );
    }

    #[test]
    fn rejects_unknown_command_and_problem() {
        assert!(parse(&sv(&["frobnicate"])).is_err());
        assert!(parse(&sv(&["solve", "nosuch"])).is_err());
        assert!(parse(&sv(&["solve", "gfl", "--mode", "warp"])).is_err());
    }

    #[test]
    fn empty_is_help() {
        let cli = parse(&[]).unwrap();
        assert_eq!(cli.command, Command::Help);
    }
}
